"""The execution-plan layer: one `LevelPlan` for every training mode.

A depth level of Alg. 2 is always the same composition — candidate draw →
engine supersplits → winner argmax → condition eval → reassign → next
totals — no matter whether the numeric search is exact or histogram, local
or mesh-sharded.  This package separates the split *strategy* (a
`SplitEngine`, woody/PLANET style) from the level *plan* that composes it,
so every mode combination runs through the SAME fused device program per
depth, including the multi-tree batch axis (DESIGN.md §7).

  engines.py   SplitEngine protocol + the local engines
               (exact numeric, histogram numeric, categorical table)
  sharded.py   the mesh engines (shard_map'd table/scan reductions,
               psum/all_gather supersplit merges)
  plan.py      LevelPlan + the fused per-depth device programs
"""
from repro.core.level.engines import (CategoricalTable, ExactNumeric,
                                      HistNumeric, LegacyFn, LevelInputs,
                                      LevelStatics, SplitEngine)
from repro.core.level.plan import LevelPlan, make_plan
from repro.core.level.sharded import (ShardedCategorical, ShardedExactNumeric,
                                      ShardedHistNumeric)

__all__ = [
    "SplitEngine", "LevelInputs", "LevelStatics",
    "ExactNumeric", "HistNumeric", "CategoricalTable", "LegacyFn",
    "ShardedExactNumeric", "ShardedHistNumeric", "ShardedCategorical",
    "LevelPlan", "make_plan",
]
