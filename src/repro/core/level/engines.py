"""SplitEngine protocol + the local (single-device) engines.

A `SplitEngine` answers ONE question per depth level: "for every open
leaf, what is the best split on my features?" — the paper's supersplit
query.  The level plan (plan.py) owns everything around that answer
(candidate draw, winner argmax, condition eval, reassignment), so an
engine only ever sees per-leaf state and returns per-leaf bests:

    numeric engines:      (gains (m_num, L+1), thresholds (m_num, L+1))
    categorical engines:  (gains (m_cat, L+1), left-masks (m_cat, L+1, V))

Engines are FROZEN, HASHABLE dataclasses: they ride through `jax.jit` as
static arguments of the fused level step, so choosing an engine chooses a
lowering, not a runtime branch.  Local engines are called per tree inside
the plan's tree-axis vmap / lax.map; mesh engines (sharded.py) declare
`batch_native = True` and are instead called ONCE per level with a leading
tree axis, outside the vmap, because `shard_map` composes with an explicit
batch axis far more robustly than with a vmap batching rule.

`LevelInputs` is the full per-tree view of the level state; every engine
reads only the fields its layout needs (the drivers pass zero-size dummies
for the rest, see `SplitEngine.needs_sorted` / `needs_bins`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import splits


class LevelInputs(NamedTuple):
    """Per-tree level state handed to engines (see tree.py for shapes).

    Batch-native engines receive the same tuple with a leading tree axis T
    on the per-tree fields (`ord_idx`, `leaf_of`, `w`, `stats`, `totals`,
    `row_counts`, `prev_tables`, `parent_of`, `sib_of`, `slot_of`); the
    shared read-only fields (`num`, `cat`, `labels`, `sorted_vals`,
    `sorted_idx`, `bin_of`, `bin_edges`) never batch.

    The last four fields are the histogram-subtraction state (DESIGN.md
    §6), present only when the plan carries tables (`st.subtract`):
    `prev_tables` holds the previous level's merged per-leaf tables
    (indexed by the previous level's leaf ids), and the three per-leaf
    maps relate the CURRENT frontier to it — `parent_of[l]` is l's parent
    leaf id at the previous level, `sib_of[l]` its sibling's current id,
    `slot_of[l]` its packed build slot (0 = table derived by subtraction).
    """
    num: jnp.ndarray           # (n, m_num) raw numeric columns
    cat: jnp.ndarray           # (n, m_cat) raw categorical columns
    labels: jnp.ndarray        # (n,) class ids / regression targets
    sorted_vals: jnp.ndarray   # (m_num, n) presorted values (or (0, 0))
    sorted_idx: jnp.ndarray    # (m_num, n) presorted row ids (or (0, 0))
    bin_of: jnp.ndarray        # (m_num, n) packed hist bucket ids (or (0, 0))
    bin_edges: jnp.ndarray     # (m_num, B) hist bucket edges (or (0, 0))
    ord_idx: jnp.ndarray       # (m_num, n) (leaf, value)-sorted order (or (0, 0))
    leaf_of: jnp.ndarray       # (n,) leaf id per row, 0 = closed
    w: jnp.ndarray             # (n,) bag weights
    stats: jnp.ndarray         # (n, S) row stats
    totals: jnp.ndarray        # (L+1, S) per-leaf stat totals
    row_counts: jnp.ndarray    # (L+1,) rows per leaf (leaf-ordered layout)
    prev_tables: jnp.ndarray = None   # (m_num, Wprev, B, S) previous level
    parent_of: jnp.ndarray = None     # (L+1,) parent leaf id at prev level
    sib_of: jnp.ndarray = None        # (L+1,) sibling's current leaf id
    slot_of: jnp.ndarray = None       # (L+1,) packed build slot, 0 = derive


class LevelStatics(NamedTuple):
    """The hashable static config shared by every engine call.

    `carry_tables`/`subtract` are per-DISPATCH statics the plan fills in
    (plan.statics defaults them off): `carry_tables` asks a histogram
    engine to also return its merged tables (the loop state of the
    subtraction recurrence); `subtract` means the inputs carry a valid
    previous level (prev_tables + maps), so only build-slot leaves are
    scattered and siblings derive by parent − sibling.
    """
    m_num: int
    m_cat: int
    max_arity: int
    num_classes: int
    num_bins: int
    impurity: str
    task: str
    min_records: float
    carry_tables: bool = False
    subtract: bool = False


class SplitEngine:
    """Base protocol.  Subclasses are frozen dataclasses (hashable)."""

    kind: str = "numeric"       # "numeric" | "categorical"
    batch_native: bool = False  # True: called once per level with a T axis
    uses_ord: bool = False      # True: wants the incremental leaf order
    needs_sorted: bool = False  # True: wants sorted_vals/sorted_idx
    needs_bins: bool = False    # True: wants bin_of/bin_edges (hist layout)
    bin_cut_thresholds: bool = False  # True: thresholds are BIN INDICES
                                # (host decodes via edges; condition eval
                                # runs on the bin cache, not float columns)
    carries_tables: bool = False  # True: supports the table-carrying
                                # subtraction protocol (st.carry_tables)

    def supersplits(self, inp: LevelInputs, st: LevelStatics, Lp: int,
                    cand: jnp.ndarray):
        """Per-tree supersplit: cand is (m, L+1) bool (leaf 0 = False)."""
        raise NotImplementedError

    def supersplits_batched(self, inp: LevelInputs, st: LevelStatics,
                            Lp: int, cand: jnp.ndarray):
        """Whole-batch supersplit (batch-native engines only): per-tree
        fields of `inp` and `cand` carry a leading tree axis T."""
        raise NotImplementedError

    def row_shards(self) -> int:
        """Row-shard count the driver must keep n divisible by (pruning)."""
        return 1

    # -- out-of-core streaming (DESIGN.md §8) -------------------------------
    #
    # A streaming-capable hist engine splits its table build into a
    # chunk recurrence: `stream_init` allocates the per-level accumulator,
    # `stream_accumulate` adds one fixed-shape row chunk (called inside
    # the jitted chunk step, once per chunk), and `stream_finalize` merges
    # the accumulator into the (T, m_num, L+1, B, S) tables the scorer
    # reads (called once per level).  Classification tables are
    # integer-valued f32, so chunked accumulation is bit-equal to the
    # single-pass scatter regardless of chunk boundaries.

    supports_stream: bool = False

    def stream_init(self, T: int, st: LevelStatics, Lp: int):
        """Zero accumulator for one level of T trees."""
        raise NotImplementedError

    def stream_accumulate(self, acc, bins, leaf, w, stats, labels,
                          st: LevelStatics, Lp: int):
        """acc + tables of one chunk: bins (m, c); leaf/w (T, c);
        stats (T, c, S); labels (c,)."""
        raise NotImplementedError

    def stream_finalize(self, acc):
        """Accumulator -> merged (T, m_num, Lp+1, B, S) tables."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Shared per-column helpers (also used by the sharded engines)
# ---------------------------------------------------------------------------

@jax.jit
def _gather_sorted_level(sorted_idx, leaf_of, w, stats):
    """Per-column gathers of the level state in presorted order."""
    return leaf_of[sorted_idx], w[sorted_idx], stats[sorted_idx]


def _numeric_supersplits(backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                         cand, Lp, impurity, task, min_records):
    """vmap the chosen exact backend over numerical columns.

    sorted_vals/sorted_idx: (m_num, n); cand: (m_num, Lp+1).
    Returns gains (m_num, Lp+1), thresholds (m_num, Lp+1).
    """
    fn = splits.NUMERIC_BACKENDS[backend]
    def per_col(v, si, cl):
        lf, ww, st = _gather_sorted_level(si, leaf_of, w, stats)
        return fn(v, lf, ww, st, cl, Lp, impurity, task, min_records)
    return jax.vmap(per_col)(sorted_vals, sorted_idx, cand)


def _categorical_supersplits(cat_cols, leaf_of, w, stats, cand, Lp, max_arity,
                             impurity, task, min_records):
    """vmap exact categorical search over columns padded to max_arity."""
    def per_col(x, cl):
        return splits.best_categorical_split(
            x, leaf_of, w, stats, cl, Lp, max_arity, impurity, task, min_records)
    return jax.vmap(per_col)(cat_cols, cand)


# ---------------------------------------------------------------------------
# Local engines
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExactNumeric(SplitEngine):
    """The paper's midpoint-exhaustive numeric search, all local backends.

    backend = "segment" (default) reads the incrementally-maintained
    (leaf, value)-sorted layout when the driver provides it (DESIGN.md §2)
    and falls back to the presorted counting-sort path otherwise;
    "scan" is the faithful Alg. 1 streaming pass; "kernel" the Pallas
    split_scan path.
    """
    backend: str = "segment"

    needs_sorted = True

    @property
    def uses_ord(self) -> bool:
        return self.backend == "segment"

    def supersplits(self, inp, st, Lp, cand):
        if self.backend == "kernel":
            from repro.kernels import ops as kops
            return kops.split_scan_supersplit(
                inp.sorted_vals, inp.sorted_idx, inp.leaf_of, inp.w,
                inp.labels, cand, Lp, st.impurity, st.task, st.min_records,
                num_classes=st.num_classes)
        if inp.ord_idx.size:
            # leaf-ordered fast path: no per-level counting sort.  Shared
            # per-leaf totals are exact for classification (integer bag
            # counts); regression reduces per column to keep the reference
            # builder's float summation order bit-for-bit.
            tot = inp.totals if st.task == "classification" else None
            lf_pos = inp.leaf_of[inp.ord_idx[0]]    # same for every column
            inbag = (inp.w > 0)[inp.ord_idx] & (lf_pos > 0)[None]
            ord_vals = jnp.take_along_axis(inp.num.T, inp.ord_idx, axis=1)
            return splits.best_numeric_split_leaf_ordered(
                ord_vals, lf_pos, inbag, inp.stats[inp.ord_idx], cand, Lp,
                st.impurity, st.task, st.min_records, totals=tot,
                row_counts=inp.row_counts)
        return _numeric_supersplits(
            self.backend, inp.sorted_vals, inp.sorted_idx, inp.leaf_of,
            inp.w, inp.stats, cand, Lp, st.impurity, st.task, st.min_records)


# ---------------------------------------------------------------------------
# Histogram-mode table building (shared by HistNumeric and the mesh engine)
# ---------------------------------------------------------------------------

def _hist_build_rows(inp, subtract, compact):
    """The (bin_of, scatter slots, w, stats, labels) a table build reads.

    Plain mode scatters every row under its raw leaf id.  Subtraction mode
    remaps rows through `slot_of` — rows of derive-slot leaves land in the
    discarded slot 0 — and, when `compact` (single-device only: the bound
    below is global, not per row shard), GATHERS the build rows into an
    n//2 buffer first, so the scatter touches at most half the rows: build
    leaves are the smaller child of every split, so their row total is
    ≤ floor(n/2).  Compaction keeps row order (nonzero is stable), so the
    per-slot accumulation order — and hence the tables — match the
    uncompacted scatter exactly.
    """
    if not subtract:
        return inp.bin_of, inp.leaf_of, inp.w, inp.stats, inp.labels
    slot_row = inp.slot_of[inp.leaf_of]                   # (n,) build slots
    if not compact:
        return inp.bin_of, slot_row, inp.w, inp.stats, inp.labels
    n = inp.leaf_of.shape[0]
    n2 = max(n // 2, 1)
    idx = jnp.nonzero(slot_row > 0, size=n2, fill_value=n)[0]
    valid = idx < n
    idxc = jnp.minimum(idx, n - 1)
    return (inp.bin_of[:, idxc],
            jnp.where(valid, slot_row[idxc], 0),
            jnp.where(valid, inp.w[idxc], 0.0),
            inp.stats[idxc], inp.labels[idxc])


def _expand_subtracted(packed, prev_tables, parent_of, sib_of, slot_of):
    """Full-width tables from packed build tables + the parent recurrence.

    packed: (m, Wb, B, S) merged build-slot tables; returns (m, L+1, B, S)
    where build leaves gather their packed slot and every derive leaf is
    `parent − sibling` — exact for classification (integer-valued counts),
    which is why the plan only enables subtraction there.
    """
    from_build = packed[:, slot_of]                       # (m, L+1, B, S)
    sib = packed[:, slot_of[sib_of]]
    derived = prev_tables[:, parent_of] - sib
    return jnp.where((slot_of > 0)[None, :, None, None], from_build, derived)


@dataclasses.dataclass(frozen=True)
class HistNumeric(SplitEngine):
    """PLANET-style histogram numeric search (DESIGN.md §6).

    Reads ONLY the bit-packed bin cache (`bin_of`, uint8/uint16): per-leaf
    (bin × stat) tables for all columns are built in one pass — the Pallas
    `feat_hist` kernel under backend="kernel", a single flat scatter
    (`splits.feature_count_tables`) otherwise — and
    `splits.best_numeric_split_histogram` scores the bucket boundaries,
    returning BIN INDICES the host decodes against the (host-side) float
    edges.  Under `st.subtract` only the smaller child of each split is
    scattered (rows compacted to an n//2 buffer) and its sibling derives
    by parent − sibling from the carried previous-level tables.
    """
    backend: str = "segment"

    needs_bins = True
    bin_cut_thresholds = True
    carries_tables = True
    supports_stream = True

    def stream_init(self, T, st, Lp):
        S = st.num_classes if st.task == "classification" else 3
        return jnp.zeros((T, st.m_num, Lp + 1, st.num_bins, S), jnp.float32)

    def stream_accumulate(self, acc, bins, leaf, w, stats, labels, st, Lp):
        return acc + jax.vmap(
            lambda lf, ww, stt: self._tables(None, st, Lp + 1, bins, lf, ww,
                                             stt, labels))(leaf, w, stats)

    def stream_finalize(self, acc):
        return acc

    def _tables(self, inp, st, W, bins, slots, w, stats, labels):
        if self.backend == "kernel":
            from repro.kernels import ops as kops
            return kops.feature_tables(
                bins, slots, w, labels, B=st.num_bins, W=W, task=st.task,
                num_classes=st.num_classes)
        return splits.feature_count_tables(bins, slots, w, stats, W - 1,
                                           st.num_bins)

    def supersplits(self, inp, st, Lp, cand):
        Wb = Lp // 2 + 1 if st.subtract else Lp + 1
        bins, slots, w, stats, labels = _hist_build_rows(
            inp, st.subtract, compact=True)
        packed = self._tables(inp, st, Wb, bins, slots, w, stats, labels)
        if st.subtract:
            tables = _expand_subtracted(packed, inp.prev_tables,
                                        inp.parent_of, inp.sib_of,
                                        inp.slot_of)
        else:
            tables = packed
        g, c = jax.vmap(
            lambda tb, cd: splits.best_numeric_split_histogram(
                tb, cd, st.impurity, st.task, st.min_records))(tables, cand)
        if st.carry_tables:
            return g, c, tables
        return g, c


@dataclasses.dataclass(frozen=True)
class CategoricalTable(SplitEngine):
    """Exact categorical search from (leaf × category × stat) count tables
    + Breiman ordering; backend="kernel" builds the tables with the Pallas
    cat_hist kernel."""
    backend: str = "segment"

    kind = "categorical"

    def supersplits(self, inp, st, Lp, cand):
        if self.backend == "kernel":
            from repro.kernels import ops as kops
            tables = kops.categorical_tables(
                inp.cat.T, inp.leaf_of, inp.w, inp.labels, V=st.max_arity,
                Lp=Lp, task=st.task, num_classes=st.num_classes)
            return jax.vmap(
                lambda tb, c: splits.best_categorical_split_from_table(
                    tb, c, st.impurity, st.task, st.min_records))(
                tables, cand)
        return _categorical_supersplits(
            inp.cat.T, inp.leaf_of, inp.w, inp.stats, cand, Lp,
            st.max_arity, st.impurity, st.task, st.min_records)


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash: one trace
class LegacyFn(SplitEngine):                    # per closure, as before
    """Adapter for a bare `supersplit_fn` closure (the pre-SplitEngine
    API).  Per-tree only: `RandomForest.fit` warns and routes these to the
    per-tree builder, because an arbitrary closure composes with neither
    the tree-axis vmap nor the batch-native protocol."""
    fn: Callable
    hist: bool = False          # hist-mode signature (bin_of, bin_edges, ...)

    @property
    def needs_sorted(self) -> bool:     # type: ignore[override]
        return not self.hist

    @property
    def needs_bins(self) -> bool:       # type: ignore[override]
        return self.hist

    def supersplits(self, inp, st, Lp, cand):
        if self.hist:
            return self.fn(inp.bin_of, inp.bin_edges, inp.leaf_of, inp.w,
                           inp.stats, cand, Lp, st.impurity, st.task,
                           st.min_records)
        return self.fn(inp.sorted_vals, inp.sorted_idx, inp.leaf_of, inp.w,
                       inp.stats, cand, Lp, st.impurity, st.task,
                       st.min_records)
