"""LevelPlan: the one execution plan every training mode runs through.

A `LevelPlan` composes a numeric and a categorical `SplitEngine` with the
static level config, and lowers one whole depth level of Alg. 2 as a
single jitted device program (the plan is a static jit argument, so
choosing engines chooses a lowering):

    candidate draw → engine supersplits → cross-feature winner argmax →
    condition evaluation (step 5) → leaf reassignment (step 6) → next
    totals (+ the incremental leaf-order partition, DESIGN.md §2)

Two program shapes, both per depth level:

  * `_fused_level_step`          — one tree (tree.build_tree)
  * `_fused_level_step_batched`  — a whole tree batch (tree.build_forest,
    DESIGN.md §3): local engines run per tree inside the tree-axis vmap /
    lax.map; batch-native (mesh) engines run ONCE on the stacked state
    before it, so sharded training keeps the same D-dispatches-per-forest
    shape as local training.

The exact/hist × local/sharded mode matrix is therefore four engine
choices into ONE plan — not four code paths (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bagging, splits
from repro.core.level.engines import (CategoricalTable, ExactNumeric,
                                      HistNumeric, LevelInputs, LevelStatics,
                                      SplitEngine)

# Dispatch/trace counters: tests assert the batched builder issues ONE
# jitted level program per depth per tree-batch (and never falls back to
# per-tree dispatches).  CALLS bump at dispatch time (in the tree.py
# drivers), TRACES at trace time.  tree.py re-exports these lists (same
# objects) under the historical names.
_STEP_CALLS = [0]          # per-tree fused level dispatches (build_tree)
_BATCH_STEP_CALLS = [0]    # batched level dispatches (build_forest)
_BATCH_STEP_TRACES = [0]   # distinct compilations of the batched program
_STREAM_CHUNK_CALLS = [0]  # streamed per-chunk dispatches (build_forest_streamed)
_STREAM_CHUNK_TRACES = [0]  # distinct compilations of the chunk program
_STREAM_SCORE_TRACES = [0]  # distinct compilations of the stream scorer

# Above this many row-state elements (T·m_num·n) the batched level step
# switches from vmap (SIMD across trees) to lax.map (sequential trees, one
# program) — the vmapped stack stops being cache-resident and measures
# ~1.5x slower on CPU.  The canonical (monkeypatchable) knob lives in
# tree.py as `_BATCH_VMAP_ELEMS`; this is its default.
_BATCH_VMAP_ELEMS_DEFAULT = 1 << 19


def _batch_vmap_elems() -> int:
    from repro.core import tree as _tree      # late: tree.py imports us
    return getattr(_tree, "_BATCH_VMAP_ELEMS", _BATCH_VMAP_ELEMS_DEFAULT)


def _pad_leaves(L: int, pad: int) -> int:
    """Pad to a power of two (recompilation count is O(log leaves))."""
    return max(pad, 1 << (L - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("Lp",))
def _leaf_totals(leaf_of, stats, w, Lp):
    inbag = (w > 0) & (leaf_of > 0)
    return jax.ops.segment_sum(jnp.where(inbag[:, None], stats, 0.0),
                               leaf_of, num_segments=Lp + 1)


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """Engines + static config; hashable, a static arg of the fused jits."""
    numeric: Optional[SplitEngine]
    categorical: Optional[SplitEngine]
    m_num: int
    m_cat: int
    max_arity: int
    num_classes: int
    m_prime: int
    usb: bool
    num_bins: int
    impurity: str
    task: str
    min_records: float
    hist_subtract: bool = True

    @property
    def statics(self) -> LevelStatics:
        return LevelStatics(
            m_num=self.m_num, m_cat=self.m_cat, max_arity=self.max_arity,
            num_classes=self.num_classes, num_bins=self.num_bins,
            impurity=self.impurity, task=self.task,
            min_records=self.min_records)

    @property
    def use_ord(self) -> bool:
        """Drivers maintain the incremental leaf order for this plan."""
        return bool(self.m_num) and self.numeric is not None \
            and self.numeric.uses_ord

    @property
    def pass_sorted(self) -> bool:
        """The level step reads sorted_vals/sorted_idx (vs zero dummies)."""
        return bool(self.m_num) and self.numeric.needs_sorted \
            and not self.use_ord

    @property
    def use_bin_cuts(self) -> bool:
        """The numeric engine reports BIN INDICES, not float thresholds:
        condition evaluation runs on the bit-packed bin cache and the host
        decodes thresholds from the (host-side) float edges — no float32
        column and no edge array inside the level program (DESIGN.md §6).
        """
        return bool(self.m_num) and self.numeric is not None \
            and self.numeric.bin_cut_thresholds

    @property
    def pass_num(self) -> bool:
        """The level step reads the raw float numeric columns (vs zero
        dummies) — every mode except the bin-cache hist fast path."""
        return bool(self.m_num) and not self.use_bin_cuts

    @property
    def pass_edges(self) -> bool:
        """The level step reads the float bucket edges on DEVICE — only
        legacy hist closures (LegacyFn), which score and return float
        thresholds themselves."""
        return bool(self.m_num) and self.numeric is not None \
            and self.numeric.needs_bins and not self.use_bin_cuts

    @property
    def carries_tables(self) -> bool:
        """Histogram subtraction is on: the level loop carries each
        level's merged per-leaf tables and every level builds only the
        smaller child of each split, deriving the sibling as
        parent − sibling.  Classification only: its table entries are
        integer-valued bag counts, so the subtraction is EXACT (bit-equal
        to a plain rebuild, which tests assert); regression tables hold
        float y-sums whose subtraction could drift in the last ulp, so
        regression always rebuilds plain.
        """
        return self.use_bin_cuts and self.numeric.carries_tables \
            and self.hist_subtract and self.task == "classification"

    @property
    def row_shards(self) -> int:
        """Row-shard count n must stay divisible by (device pruning).

        Both engines constrain it (a sharded categorical engine can ride a
        local numeric one), so the bound is their lcm.
        """
        return math.lcm(
            self.numeric.row_shards() if self.numeric is not None else 1,
            self.categorical.row_shards() if self.categorical is not None
            else 1)


def make_plan(params, *, m_num: int, m_cat: int, max_arity: int,
              num_classes: int, m_prime: int,
              engine: Optional[SplitEngine] = None,
              cat_engine: Optional[SplitEngine] = None) -> LevelPlan:
    """Resolve a LevelPlan from TreeParams + optional engine overrides.

    Defaults: the local engine for `params.split_mode` on
    `params.backend`, local categorical tables.  A numeric `engine` must
    match the split mode (a hist engine scores bucket boundaries, an exact
    engine needs the presorted order).
    """
    hist = params.split_mode == "hist"
    if engine is None:
        engine = (HistNumeric(params.backend) if hist
                  else ExactNumeric(params.backend))
    elif engine.kind != "numeric":
        raise ValueError(f"numeric engine expected, got {engine!r}")
    elif hist and not engine.needs_bins:
        raise ValueError(
            f"split_mode='hist' needs a histogram engine, got {engine!r}")
    elif not hist and engine.needs_bins:
        raise ValueError(
            f"split_mode='exact' cannot use histogram engine {engine!r}")
    if cat_engine is None:
        cat_engine = CategoricalTable(params.backend)
    elif cat_engine.kind != "categorical":
        raise ValueError(f"categorical engine expected, got {cat_engine!r}")
    return LevelPlan(
        numeric=engine if m_num else None,
        categorical=cat_engine if m_cat else None,
        m_num=m_num, m_cat=m_cat, max_arity=max_arity,
        num_classes=num_classes, m_prime=m_prime, usb=params.usb,
        num_bins=params.num_bins, impurity=params.impurity,
        task=params.task, min_records=params.min_records,
        hist_subtract=getattr(params, "hist_subtract", True))


# ---------------------------------------------------------------------------
# The fused level step (one jitted device program per depth)
# ---------------------------------------------------------------------------

def _partition_leaf_order(ord_idx, lf_pos, bits, new_left, new_right,
                          row_counts, key_counts):
    """Advance the per-column (leaf, value)-sorted order to the next level.

    Children occupy consecutive id ranges in parent order (left id <
    right id, parents in id order, closed = 0), so the stable counting sort
    by the NEW leaf id reduces to: closed rows to the front (stable), then
    a stable left/right partition inside each parent's contiguous block —
    O(n) work with ONE cumsum and ONE scatter per column, no sort.
    Relative row order inside every child equals the parent's
    (value-ascending), exactly what a stable sort would produce, so the
    `segment` backend's summation order — and hence its float results —
    are preserved bit-for-bit.

    The block structure is column-independent (same leaf histogram in every
    column), so everything except the row permutation itself — `lf_pos`,
    the current `row_counts` (L+1,) and next-level `key_counts` (2L+1,)
    histograms, block starts, target offsets — is computed once.  Only the
    1-bit condition outcome `bits` (row-indexed) is gathered per column.

    Accepts an optional LEADING TREE AXIS on every argument
    (ord_idx (T, m, n), the rest (T, ...)): the batched level step calls it
    this way, outside its tree-axis vmap, so the permutation lands in ONE
    flat scatter over all T·m columns — XLA lowers a batched-operand
    scatter (what vmap would produce) far slower than the same scatter on a
    flattened index space (~2x on CPU, measured).  The per-tree call takes
    the same flat-scatter path with T = 1.
    """
    batched = ord_idx.ndim == 3
    if not batched:
        ord_idx, lf_pos, bits = ord_idx[None], lf_pos[None], bits[None]
        new_left, new_right = new_left[None], new_right[None]
        row_counts, key_counts = row_counts[None], key_counts[None]
    B, m, n = ord_idx.shape

    def shared(lf_pos, new_left, new_right, row_counts, key_counts):
        # parents either split wholly or close wholly, so a block is
        # all-closed or all-left/right; closed rows keep their block order,
        # preceded by the closed rows of earlier parents
        parent_closed = new_left == 0                         # (Lp+1,)
        closed_sizes = jnp.where(parent_closed, row_counts, 0)
        closed_before = jnp.cumsum(closed_sizes) - closed_sizes
        offs = jnp.cumsum(key_counts) - key_counts            # per new key
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), lf_pos[1:] != lf_pos[:-1]])
        start_idx = jax.lax.cummax(jnp.where(is_start, jnp.arange(n), -1))
        in_block = jnp.arange(n) - start_idx                  # rank in block
        return (start_idx, in_block, parent_closed[lf_pos],
                closed_before[lf_pos] + in_block,             # (n,) shared
                offs[new_left[lf_pos]], offs[new_right[lf_pos]])

    start_idx, in_block, closed_here, pos_closed, offs_l, offs_r = \
        jax.vmap(shared)(lf_pos, new_left, new_right, row_counts, key_counts)

    wl = jax.vmap(lambda b, oi: b[oi])(                       # went LEFT
        bits, ord_idx.reshape(B, m * n)).reshape(B, m, n)
    cl = jnp.cumsum(wl.astype(jnp.int32), axis=2) - wl
    si = jnp.broadcast_to(start_idx[:, None, :], (B, m, n))
    left_rank = cl - jnp.take_along_axis(cl, si, axis=2)
    pos = jnp.where(
        closed_here[:, None, :], pos_closed[:, None, :],
        jnp.where(wl, offs_l[:, None, :] + left_rank,
                  offs_r[:, None, :] + in_block[:, None, :] - left_rank))
    if B * m * n < 2 ** 31:
        base = (jnp.arange(B * m, dtype=jnp.int32) * n).reshape(B, m, 1)
        out = jnp.zeros((B * m * n,), ord_idx.dtype).at[
            (pos + base).reshape(-1)].set(ord_idx.reshape(-1),
                                          unique_indices=True
                                          ).reshape(B, m, n)
    else:
        # the flat index space would overflow int32 (x64 is off); fall back
        # to per-column scatters, whose indices stay < n
        out = jax.vmap(jax.vmap(
            lambda p, o: jnp.zeros_like(o).at[p].set(
                o, unique_indices=True)))(pos, ord_idx)
    return out if batched else out[0]


def _eval_conditions_core(num, cat, leaf_of, feat_of_leaf, thr_of_leaf,
                          iscat_of_leaf, mask_of_leaf, m_num, bin_of=None):
    """Alg. 2 step 5: evaluate the winning condition of each sample's leaf.

    Returns bits (n,) bool — True = LEFT.  In the distributed engine this is
    the 1-bit-per-sample payload that gets allreduced (see distributed.py).

    When `bin_of` is given (the hist fast path, plan.use_bin_cuts) the
    numeric condition is evaluated on the bit-packed bin cache instead of
    the float columns: `thr_of_leaf` then holds the winning BIN INDEX and
    `bin <= cut  <=>  x <= edges[cut]` (presort.quantize_edges), so the
    partition is identical while the program never reads float32 columns.
    """
    f = feat_of_leaf[leaf_of]                                   # (n,)
    jn = jnp.clip(f, 0, max(m_num - 1, 0))
    jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
    if bin_of is not None and bin_of.size:
        xbin = bin_of[jn, jnp.arange(leaf_of.shape[0])].astype(jnp.int32)
        num_bit = xbin <= thr_of_leaf[leaf_of].astype(jnp.int32)
    else:
        xnum = (jnp.take_along_axis(num, jn[:, None], axis=1)[:, 0]
                if num.size else jnp.zeros_like(leaf_of, jnp.float32))
        num_bit = xnum <= thr_of_leaf[leaf_of]
    xcat = jnp.take_along_axis(cat, jc[:, None], axis=1)[:, 0] if cat.size else jnp.zeros_like(leaf_of)
    cat_bit = mask_of_leaf[leaf_of, xcat]
    return jnp.where(iscat_of_leaf[leaf_of], cat_bit, num_bit)


def _candidates(fkey, depth, splittable_p, Lp, plan):
    """Per-leaf candidate mask (m, L+1), leaf 0 and unsplittable rows False.

    One tree.  Deterministic in (fkey, depth, leaf row): the batched step
    recomputes the identical mask outside the vmap for batch-native
    engines (`_candidates_batched`) — same fold_in chain, bit-identical.
    """
    m = plan.m_num + plan.m_cat
    cand = bagging.candidate_features(fkey, depth, Lp, m, plan.m_prime,
                                      plan.usb)
    cand = cand & splittable_p[1:, None]
    return jnp.concatenate([jnp.zeros((1, m), bool), cand], 0)   # (L+1, m)


def _candidates_batched(fkeys, depth, splittable_p, Lp, plan):
    """(T, m, L+1) candidate masks for the whole batch."""
    def per_tree(fk, sp):
        return _candidates(fk, depth, sp, Lp, plan).T
    return jax.vmap(per_tree)(fkeys, splittable_p)


def _level_step_core(num, cat, labels, sorted_vals, sorted_idx, bin_of,
                     bin_edges, ord_idx, leaf_of, w, stats, splittable_p,
                     totals, row_counts, prev_tables, parent_of, sib_of,
                     slot_of, fkey, depth, *, plan, Lp, need_partition,
                     subtract=False, fused_tail=True, pre_num=None,
                     pre_cat=None, pre_tables=None):
    """One whole depth level of Alg. 2 as a single device program.

    Steps 3-7 fused: candidate feature draw, numeric + categorical engine
    supersplits, partial-supersplit merge (cross-feature argmax), condition
    evaluation, leaf reassignment, and the next level's leaf totals.  Only
    the returned per-leaf struct (winning feature, gain, threshold,
    category mask, split bitmap) is fetched by the host; the row-indexed
    state (`leaf_of`, the per-column leaf order) stays device-resident —
    as do the carried histogram tables when the plan runs the subtraction
    recurrence (`prev_tables` + the parent/sib/slot maps; `subtract` is
    the static saying they are valid this level, i.e. not the root).

    `pre_num`/`pre_cat` carry the (gains, thresholds/masks) a batch-native
    engine already computed for this tree OUTSIDE the tree-axis vmap; when
    given, the corresponding engine is not called here (`pre_tables` are
    the new carried tables it returned alongside).
    """
    m_num, m_cat = plan.m_num, plan.m_cat
    L1 = Lp + 1
    n = leaf_of.shape[0]

    # Alg. 2 step 3: seeded per-leaf candidate features (paper §2.2/§2.4)
    cand_p = _candidates(fkey, depth, splittable_p, Lp, plan)

    inp = LevelInputs(num=num, cat=cat, labels=labels,
                      sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                      bin_of=bin_of, bin_edges=bin_edges, ord_idx=ord_idx,
                      leaf_of=leaf_of, w=w, stats=stats, totals=totals,
                      row_counts=row_counts, prev_tables=prev_tables,
                      parent_of=parent_of, sib_of=sib_of, slot_of=slot_of)
    carries = plan.carries_tables
    statics = plan.statics._replace(carry_tables=carries, subtract=subtract)

    gains_parts, masks = [], None
    new_tables = pre_tables
    thr_num = jnp.zeros((max(m_num, 1), L1), jnp.float32)
    if m_num:
        if pre_num is not None:
            g, t = pre_num
        else:
            res = plan.numeric.supersplits(inp, statics, Lp,
                                           cand_p[:, :m_num].T)
            if carries:
                g, t, new_tables = res
            else:
                g, t = res
        gains_parts.append(g)
        thr_num = t
    if m_cat:
        if pre_cat is not None:
            g, masks = pre_cat
        else:
            g, masks = plan.categorical.supersplits(inp, statics, Lp,
                                                    cand_p[:, m_num:].T)
        gains_parts.append(g)

    all_gains = jnp.concatenate(gains_parts, axis=0)            # (m, L1)

    # tree builder merges partial supersplits (Alg. 2 step 3, final argmax)
    best_feat = jnp.argmax(all_gains, axis=0).astype(jnp.int32)  # (L1,)
    best_gain = jnp.take_along_axis(all_gains, best_feat[None], 0)[0]
    will_split = splittable_p & jnp.isfinite(best_gain) & (best_gain > 1e-9)

    # children get consecutive 1-based ids in leaf order (Alg. 2 step 6)
    ks = jnp.cumsum(will_split.astype(jnp.int32))
    new_left = jnp.where(will_split, 2 * ks - 1, 0).astype(jnp.int32)
    new_right = jnp.where(will_split, 2 * ks, 0).astype(jnp.int32)

    feat_of_leaf = jnp.where(will_split, best_feat, 0).astype(jnp.int32)
    iscat_of_leaf = will_split & (best_feat >= m_num) if m_cat else \
        jnp.zeros((L1,), bool)
    thr_sel = jnp.take_along_axis(
        thr_num, jnp.clip(best_feat, 0, max(m_num - 1, 0))[None], 0)[0]
    thr_of_leaf = jnp.where(will_split & ~iscat_of_leaf, thr_sel, 0.0)
    if m_cat:
        jc = jnp.clip(best_feat - m_num, 0, m_cat - 1)
        mask_sel = masks[jc, jnp.arange(L1)]                    # (L1, V)
        mask_of_leaf = jnp.where(iscat_of_leaf[:, None], mask_sel, False)
    else:
        mask_of_leaf = jnp.zeros((L1, plan.max_arity), bool)

    # Alg. 2 steps 5-6: 1-bit condition per sample, reassign to children
    bits = _eval_conditions_core(num, cat, leaf_of, feat_of_leaf,
                                 thr_of_leaf, iscat_of_leaf, mask_of_leaf,
                                 m_num,
                                 bin_of=bin_of if plan.use_bin_cuts
                                 else None)
    new_leaf_of = jnp.where(
        leaf_of > 0,
        jnp.where(bits, new_left[leaf_of], new_right[leaf_of]), 0)

    use_ord = plan.use_ord
    struct = {"best_feat": best_feat, "best_gain": best_gain,
              "thr": thr_of_leaf, "mask": mask_of_leaf,
              "will_split": will_split}
    if not fused_tail:
        # batched mode: the scatter-backed reductions (next totals, key
        # counts, order partition) run OUTSIDE the tree-axis vmap, on a
        # flattened (tree, segment) index space — vmap would lower them as
        # batched-operand scatters, ~2x slower on CPU.  Hand back the
        # per-tree pieces the wrapper needs.
        part = (bits, new_left, new_right) if use_ord else None
        return struct, new_leaf_of, ord_idx, None, part, new_tables

    # next-level totals (node values / counts / splittable for depth+1)
    inb = (w > 0) & (new_leaf_of > 0)
    next_totals = jax.ops.segment_sum(jnp.where(inb[:, None], stats, 0.0),
                                      new_leaf_of, num_segments=2 * Lp + 1)

    if use_ord or carries:
        # next level's per-child row counts: the ord layout's row_counts,
        # and (subtraction) what the host uses to pick each split's
        # SMALLER child as the build leaf
        key_counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32),
                                         new_leaf_of, num_segments=2 * Lp + 1)
        struct["key_counts"] = key_counts
    if use_ord:
        if need_partition:
            lf_pos = leaf_of[ord_idx[0]]
            new_ord_idx = _partition_leaf_order(
                ord_idx, lf_pos, bits, new_left, new_right, row_counts,
                key_counts)
        else:       # the next level cannot split again (max depth reached)
            new_ord_idx = ord_idx
    else:
        new_ord_idx = ord_idx
    return struct, new_leaf_of, new_ord_idx, next_totals, None, new_tables


_LEVEL_STATICS = ("plan", "Lp", "need_partition", "subtract")


@functools.partial(jax.jit, static_argnames=_LEVEL_STATICS)
def _fused_level_step(num, cat, labels, sorted_vals, sorted_idx, bin_of,
                      bin_edges, ord_idx, leaf_of, w, stats, splittable_p,
                      totals, row_counts, prev_tables, parent_of, sib_of,
                      slot_of, fkey, depth, *, plan, Lp, need_partition,
                      subtract=False):
    """The per-tree fused level step (see `_level_step_core`)."""
    struct, new_leaf_of, new_ord_idx, next_totals, _, new_tables = \
        _level_step_core(
            num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
            ord_idx, leaf_of, w, stats, splittable_p, totals, row_counts,
            prev_tables, parent_of, sib_of, slot_of, fkey, depth, plan=plan,
            Lp=Lp, need_partition=need_partition, subtract=subtract)
    return struct, new_leaf_of, new_ord_idx, next_totals, new_tables


@functools.partial(jax.jit, static_argnames=_LEVEL_STATICS)
def _fused_level_step_batched(num, cat, labels, sorted_vals, sorted_idx,
                              bin_of, bin_edges, ord_idx, leaf_of, w, stats,
                              splittable_p, totals, row_counts, prev_tables,
                              parent_of, sib_of, slot_of, fkeys, depth,
                              *, plan, Lp, need_partition, subtract=False):
    """One depth level of EVERY tree in a batch as a single device program.

    Trees are independent, so the whole fused level step — candidate draw,
    numeric + categorical supersplit, winner argmax, condition evaluation,
    leaf reassignment, next-level totals, incremental leaf-order partition —
    is `vmap`ped over a leading tree axis T.  Shared read-only inputs (the
    raw columns, labels, the forest-wide presorted order, the bucket
    state) broadcast; the per-tree state batches:

        num (n, m_num), cat (n, m_cat), labels (n,),
        sorted_vals/sorted_idx (m_num, n), bin_of/bin_edges  [shared]
        ord_idx (T, m_num, n), leaf_of (T, n), w (T, n), stats (T, n, S),
        splittable_p (T, Lp+1), totals (T, Lp+1, S), row_counts (T, Lp+1),
        fkeys (T, key)                                       [batched]

    `Lp` is the batch-wide padded frontier width (max over the batch's
    trees); trees with fewer open leaves — or none, having finished early —
    are masked through `splittable_p`, which zeroes their candidate sets so
    every gain is −inf and `will_split` stays False.  Because
    `bagging.candidate_features` is padding-independent (per-leaf fold-in),
    batching under the shared `Lp` is bit-identical per tree to the
    per-tree `_fused_level_step` under that tree's own padding — the
    property tests/test_forest_batch.py asserts against the reference
    builder.  The Pallas paths (`split_scan`, `cat_hist`) batch through
    `pallas_call`'s vmap rule, which folds the tree axis into the kernel
    grid — still one device program.

    BATCH-NATIVE engines (the mesh-sharded ones) are called once, here,
    on the stacked (T, ...) state BEFORE the tree-axis vmap — shard_map
    composes with an explicit leading batch axis, not with a vmap batching
    rule — and their per-tree (gains, thresholds/masks) slices flow into
    the vmapped core as `pre_num`/`pre_cat`.  Sharded training therefore
    inherits the tree batch, the early-finish masking and the flat-scatter
    tail with no special-cased host loop.

    Two lowering strategies, chosen statically by batch working-set size
    (`tree._BATCH_VMAP_ELEMS`):

      * SIMD across trees (`vmap` of the core, scatters flattened over the
        (tree, segment) index space) when the batch's row state is
        cache-resident — the fast path at small n, where dispatch overhead
        dominates and cross-tree vectorization is free;
      * sequential trees (`lax.map` of the per-tree core) when the stacked
        state would thrash cache (measured ~1.5x slower under vmap on CPU
        at T=16, n=100k) — still ONE device program per level, so the
        T·D → D dispatch/host-sync amortization is kept at every size.

    Returns the per-tree struct dict and next-level state, all with the
    leading T axis; the host fetches the structs in ONE transfer per level.
    """
    _BATCH_STEP_TRACES[0] += 1
    T, n = leaf_of.shape
    m_num, m_cat = plan.m_num, plan.m_cat
    use_ord = plan.use_ord
    carries = plan.carries_tables

    # batch-native (mesh) engines: one sharded search for the whole batch
    pres: list = []
    pre_tables = None
    has_pre_num = bool(m_num) and plan.numeric.batch_native
    has_pre_cat = bool(m_cat) and plan.categorical.batch_native
    if has_pre_num or has_pre_cat:
        cand_b = _candidates_batched(fkeys, depth, splittable_p, Lp, plan)
        inp_b = LevelInputs(num=num, cat=cat, labels=labels,
                            sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                            bin_of=bin_of, bin_edges=bin_edges,
                            ord_idx=ord_idx, leaf_of=leaf_of, w=w,
                            stats=stats, totals=totals,
                            row_counts=row_counts, prev_tables=prev_tables,
                            parent_of=parent_of, sib_of=sib_of,
                            slot_of=slot_of)
        statics_b = plan.statics._replace(carry_tables=carries,
                                          subtract=subtract)
        if has_pre_num:
            res = plan.numeric.supersplits_batched(
                inp_b, statics_b, Lp, cand_b[:, :m_num])
            if carries:
                pre_tables = res[2]      # carried OUTSIDE the tree vmap
                res = res[:2]
            pres += list(res)
        if has_pre_cat:
            pres += list(plan.categorical.supersplits_batched(
                inp_b, statics_b, Lp, cand_b[:, m_num:]))

    def _unpack_pre(rest):
        pn = pc = None
        if has_pre_num:
            pn, rest = (rest[0], rest[1]), rest[2:]
        if has_pre_cat:
            pc = (rest[0], rest[1])
        return pn, pc

    if T * max(m_num, 1) * n > _batch_vmap_elems():
        # cache-bound regime: run the trees sequentially INSIDE the program
        def body(args):
            (ord_t, leaf_t, w_t, stats_t, sp_t, tot_t, rc_t, pt_t, par_t,
             sib_t, slot_t, fk_t) = args[:12]
            pn, pc = _unpack_pre(args[12:])
            s, nl, no, nt, _, ntab = _level_step_core(
                num, cat, labels, sorted_vals, sorted_idx, bin_of,
                bin_edges, ord_t, leaf_t, w_t, stats_t, sp_t, tot_t, rc_t,
                pt_t, par_t, sib_t, slot_t, fk_t, depth, plan=plan, Lp=Lp,
                need_partition=need_partition, subtract=subtract,
                fused_tail=True, pre_num=pn, pre_cat=pc)
            return s, nl, no, nt, ntab

        struct, new_leaf_of, new_ord_idx, next_totals, new_tables = \
            jax.lax.map(
                body, tuple([ord_idx, leaf_of, w, stats, splittable_p,
                             totals, row_counts, prev_tables, parent_of,
                             sib_of, slot_of, fkeys] + pres))
        if pre_tables is not None:
            new_tables = pre_tables
        # rows closed in EVERY tree: the (free) batched-pruning trigger —
        # the driver reads it from the fetched struct instead of issuing a
        # separate reduction + host sync per level
        struct = dict(struct, closed_rows=jnp.sum(
            ~(new_leaf_of > 0).any(axis=0)))
        return struct, new_leaf_of, new_ord_idx, next_totals, new_tables

    def vcore(num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
              ord_t, leaf_t, w_t, stats_t, sp_t, tot_t, rc_t, pt_t, par_t,
              sib_t, slot_t, fk_t, depth, *rest):
        pn, pc = _unpack_pre(rest)
        return _level_step_core(
            num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
            ord_t, leaf_t, w_t, stats_t, sp_t, tot_t, rc_t, pt_t, par_t,
            sib_t, slot_t, fk_t, depth, plan=plan, Lp=Lp,
            need_partition=need_partition, subtract=subtract,
            fused_tail=False, pre_num=pn, pre_cat=pc)

    in_axes = tuple([None] * 7 + [0] * 12 + [None] + [0] * len(pres))
    struct, new_leaf_of, _, _, part, new_tables = \
        jax.vmap(vcore, in_axes=in_axes)(
            num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
            ord_idx, leaf_of, w, stats, splittable_p, totals, row_counts,
            prev_tables, parent_of, sib_of, slot_of, fkeys, depth, *pres)
    if pre_tables is not None:
        new_tables = pre_tables

    # scatter-backed tail on the FLAT (tree, segment) index space: per-tree
    # results are bit-identical (each tree's rows accumulate in the same
    # order as in the per-tree program) but the scatters lower ~2x faster
    # than their vmapped form on CPU
    struct = dict(struct, closed_rows=jnp.sum(      # see the map branch
        ~(new_leaf_of > 0).any(axis=0)))
    L2 = 2 * Lp + 1
    flat_ids = (new_leaf_of
                + jnp.arange(T, dtype=jnp.int32)[:, None] * L2).reshape(-1)
    inb = (w > 0) & (new_leaf_of > 0)
    next_totals = jax.ops.segment_sum(
        jnp.where(inb.reshape(-1)[:, None], stats.reshape(T * n, -1), 0.0),
        flat_ids, num_segments=T * L2).reshape(T, L2, -1)
    if use_ord or carries:
        key_counts = jax.ops.segment_sum(
            jnp.ones((T * n,), jnp.int32), flat_ids,
            num_segments=T * L2).reshape(T, L2)
        struct = dict(struct, key_counts=key_counts)
    if use_ord:
        if need_partition:
            bits, new_left, new_right = part
            lf_pos = jax.vmap(lambda lf, oi: lf[oi])(leaf_of, ord_idx[:, 0])
            new_ord_idx = _partition_leaf_order(
                ord_idx, lf_pos, bits, new_left, new_right, row_counts,
                key_counts)
        else:
            new_ord_idx = ord_idx
    else:
        new_ord_idx = ord_idx
    return struct, new_leaf_of, new_ord_idx, next_totals, new_tables


# ---------------------------------------------------------------------------
# Out-of-core streaming level steps (DESIGN.md §8)
# ---------------------------------------------------------------------------
#
# `tree.build_forest_streamed` splits the fused level step into three
# jitted programs so the n-sized state never has to exist on device:
#
#   _stream_chunk_step     per chunk: replay the PREVIOUS level's winning
#                          conditions on the chunk's bin block (the same
#                          `_eval_conditions_core` bin fast path),
#                          recompute row stats, and fold the chunk into
#                          the engine's table accumulator.  Statics are
#                          (plan, Lp, Lpp, root, need_tables) — the padded
#                          widths change O(log L) times per fit, so chunk
#                          iteration NEVER retraces per chunk.
#   _stream_finalize_step  per level: merge the accumulator (the sharded
#                          engine's one psum) and reduce the per-leaf
#                          totals the host reads for node values.
#   _stream_score_step     per level: candidate draw + histogram scoring +
#                          the EXACT `_level_step_core` winner/child-id
#                          formulas, on (T, m, L+1, B, S) tables alone —
#                          engine-independent, no row state.
#
# Classification tables are integer-valued f32, so the chunked
# accumulation is bit-equal to the single-pass scatter; everything
# downstream of the tables is shared arithmetic with the in-memory path,
# which is what makes streamed fits node-for-node identical.

_STREAM_CHUNK_STATICS = ("plan", "Lp", "Lpp", "root", "need_tables")


@functools.partial(jax.jit, static_argnames=_STREAM_CHUNK_STATICS)
def _stream_chunk_step(bins_c, labels_c, w_c, leaf_prev_c, feat_of_leaf,
                       cut_of_leaf, new_left, new_right, tables, *,
                       plan, Lp, Lpp, root, need_tables):
    """Fold one fixed-shape row chunk into the level accumulator.

    bins_c (m_num, c) packed; labels_c (c,); w_c/leaf_prev_c (T, c);
    feat_of_leaf/cut_of_leaf/new_left/new_right (T, Lpp+1) — the previous
    level's decisions (unused when `root`).  Returns (leaf_c (T, c) — the
    chunk's CURRENT-level leaf ids, fetched back to the host-resident
    assignment — and the updated accumulator).  Padding rows ride with
    w = 0 and leaf_prev = 0: they stay closed and contribute zero.
    """
    _STREAM_CHUNK_TRACES[0] += 1
    c = labels_c.shape[0]
    statics = plan.statics

    if root:
        leaf_c = leaf_prev_c
    else:
        def reassign(lf, feat, cut, nl, nr):
            jn = jnp.clip(feat[lf], 0, max(plan.m_num - 1, 0))
            xbin = bins_c[jn, jnp.arange(c)].astype(jnp.int32)
            bit = xbin <= cut[lf].astype(jnp.int32)
            return jnp.where(lf > 0, jnp.where(bit, nl[lf], nr[lf]), 0)
        leaf_c = jax.vmap(reassign)(leaf_prev_c, feat_of_leaf, cut_of_leaf,
                                    new_left, new_right)

    stats_c = jax.vmap(lambda ww: splits.row_stats(
        labels_c, ww, plan.num_classes, plan.task))(w_c)
    if need_tables:
        tables = plan.numeric.stream_accumulate(
            tables, bins_c, leaf_c, w_c, stats_c, labels_c, statics, Lp)
    else:
        # final level: no more splits to score — accumulate only the
        # per-leaf stat totals (T, Lp+1, S) for the node values
        def tot(lf, ww, stt):
            inb = (ww > 0) & (lf > 0)
            return jax.ops.segment_sum(jnp.where(inb[:, None], stt, 0.0),
                                       lf, num_segments=Lp + 1)
        tables = tables + jax.vmap(tot)(leaf_c, w_c, stats_c)
    return leaf_c, tables


@functools.partial(jax.jit, static_argnames=("plan",))
def _stream_finalize_step(tables, *, plan):
    """Merge the chunk accumulator and reduce per-leaf totals.

    Returns (merged (T, m, L+1, B, S) tables, totals (T, L+1, S)).  The
    totals come from feature 0's table summed over bins — for integer
    classification stats this equals the direct per-row segment_sum
    bit-for-bit (every in-bag row lands in exactly one bin)."""
    merged = plan.numeric.stream_finalize(tables)
    return merged, merged[:, 0].sum(axis=2)


@functools.partial(jax.jit, static_argnames=("plan", "Lp"))
def _stream_score_step(tables, splittable_p, fkeys, depth, *, plan, Lp):
    """Score one level from merged tables: `_level_step_core`'s candidate
    draw → histogram scoring → winner argmax → child-id assignment, with
    no row state (numeric hist only, so the m_cat branches drop out).
    Returns the per-tree decision struct; `thr` holds winning BIN INDICES
    (plan.use_bin_cuts) and `new_left`/`new_right`/`feat_of_leaf` feed the
    next level's chunk reassignment."""
    _STREAM_SCORE_TRACES[0] += 1

    def per_tree(tb, sp, fk):
        cand_p = _candidates(fk, depth, sp, Lp, plan)           # (L+1, m)
        g, cuts = jax.vmap(
            lambda t, cd: splits.best_numeric_split_histogram(
                t, cd, plan.impurity, plan.task, plan.min_records))(
            tb, cand_p[:, :plan.m_num].T)
        best_feat = jnp.argmax(g, axis=0).astype(jnp.int32)
        best_gain = jnp.take_along_axis(g, best_feat[None], 0)[0]
        will_split = sp & jnp.isfinite(best_gain) & (best_gain > 1e-9)
        ks = jnp.cumsum(will_split.astype(jnp.int32))
        new_left = jnp.where(will_split, 2 * ks - 1, 0).astype(jnp.int32)
        new_right = jnp.where(will_split, 2 * ks, 0).astype(jnp.int32)
        feat_of_leaf = jnp.where(will_split, best_feat, 0).astype(jnp.int32)
        thr_sel = jnp.take_along_axis(
            cuts, jnp.clip(best_feat, 0, max(plan.m_num - 1, 0))[None], 0)[0]
        thr_of_leaf = jnp.where(will_split, thr_sel, 0.0)
        return {"best_feat": best_feat, "best_gain": best_gain,
                "thr": thr_of_leaf, "will_split": will_split,
                "new_left": new_left, "new_right": new_right,
                "feat_of_leaf": feat_of_leaf}

    return jax.vmap(per_tree)(tables, splittable_p, fkeys)
