"""Mesh-sharded SplitEngines (paper §2 worker topology → shard_map).

Topology mapping (DESIGN.md §5):

  * `feature_axis` ("model") = the splitters: feature columns are sharded
    over it, each device searching optimal splits only on its own columns
    (paper: "each worker is assigned to a subset of columns ... read
    sequentially").
  * `row_axis` ("data") = row shards.  For the exact engine these are
    range-partitions of the PRESORTED order (beyond-paper 2-D extension):
    shard r of a column holds sorted rows [r·n/w, (r+1)·n/w), and exactness
    is preserved by resuming each shard's pass from the previous shard's
    histogram/value state — an all_gather of (ℓ+1)·S floats per leaf
    histogram, tiny compared to the data.  For the histogram and
    categorical engines rows shard in PLAIN row order and a single `psum`
    merges the fixed-size (ℓ+1)·V·S count tables — the paper's
    network-complexity contrast, executable side by side.

Every engine here is `batch_native`: the fused level step calls it ONCE
per depth with a leading tree axis T, and the shard_map body vmaps over
trees INSIDE the mesh program.  Sharded training therefore inherits the
multi-tree batch axis, the early-finish masking, and the device-resident
pruning of the batched builder with no special-cased host loop — D (not
T·D) device dispatches per forest, same as local training.

Engines also implement `__call__` with the original `supersplit_fn`
signatures, so existing call sites (launch/dryrun.py, older tests) keep
working unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import splits
from repro.core.level.engines import SplitEngine

try:  # jax>=0.6 stable name, fall back to experimental
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") else _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def _shmap(f, mesh, in_specs, out_specs):
    try:    # jax>=0.6 spells the replication check "check_vma"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # jax 0.4.x spells it "check_rep"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class _MeshEngine(SplitEngine):
    mesh: object = None         # jax.sharding.Mesh (hashable)
    feature_axis: str = "model"
    row_axis: Optional[str] = "data"

    batch_native = True

    def row_shards(self) -> int:
        if self.row_axis is None:
            return 1
        return int(self.mesh.shape[self.row_axis])


# ---------------------------------------------------------------------------
# Exact numeric engine: columns over "model", presorted rows over "data"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedExactNumeric(_MeshEngine):
    """Exact supersplit with columns and (optionally) presorted rows sharded.

    Per column: each row shard computes (a) its local per-leaf stat totals
    and last in-bag value, (b) all_gathers them over `row_axis` (payload
    (L+1)·S floats — independent of n), (c) forms the exclusive shard
    prefix (h_init, v_init) and GLOBAL totals, and (d) runs the exact
    backend on its local slice resuming from that state.  Partial bests
    merge with a first-max over shards, matching the sequential scan
    order's tie-breaking.  `row_axis=None` is the paper's column-only
    splitter layout (rows replicated, no collectives).
    """
    backend: str = "segment"

    needs_sorted = True

    def supersplits(self, inp, st, Lp, cand):
        g, t = self._search(inp.sorted_vals, inp.sorted_idx,
                            inp.leaf_of[None], inp.w[None], inp.stats[None],
                            cand[None], Lp, st.impurity, st.task,
                            st.min_records)
        return g[0], t[0]

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.sorted_vals, inp.sorted_idx, inp.leaf_of,
                            inp.w, inp.stats, cand, Lp, st.impurity,
                            st.task, st.min_records)

    def __call__(self, sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                 Lp, impurity, task, min_records):
        """Legacy per-tree supersplit_fn signature."""
        g, t = self._search(sorted_vals, sorted_idx, leaf_of[None], w[None],
                            stats[None], cand[None], Lp, impurity, task,
                            min_records)
        return g[0], t[0]

    def _search(self, sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                Lp, impurity, task, min_records):
        F, R = self.feature_axis, self.row_axis
        fn_backend = splits.NUMERIC_BACKENDS[self.backend]

        def local(sv, si, cl, lf, ww, stt):
            # sv/si: (m_loc, n_loc) shard of the presorted order (GLOBAL
            # row ids); cl (T, m_loc, L+1); lf/ww (T, n); stt (T, n, S)
            # replicated — the paper's splitter memory layout ("Sliq/R and
            # DRF duplicate the class list in each worker").
            def per_tree(cl_t, lf_t, ww_t, st_t):
                def per_col(v, s, c):
                    lfs, wws, sts = lf_t[s], ww_t[s], st_t[s]
                    if R is None:
                        return fn_backend(v, lfs, wws, sts, c, Lp, impurity,
                                          task, min_records)
                    inbag = (wws > 0) & (lfs > 0)
                    contrib = jnp.where(inbag[:, None], sts, 0.0)
                    loc_tot = jax.ops.segment_sum(contrib, lfs,
                                                  num_segments=Lp + 1)
                    loc_last = jax.ops.segment_max(
                        jnp.where(inbag, v, -jnp.inf), lfs,
                        num_segments=Lp + 1)
                    all_tot = jax.lax.all_gather(loc_tot, R)   # (W, L+1, S)
                    all_last = jax.lax.all_gather(loc_last, R)  # (W, L+1)
                    r = jax.lax.axis_index(R)
                    W = all_tot.shape[0]
                    before = (jnp.arange(W) < r)[:, None, None]
                    h_init = jnp.sum(jnp.where(before, all_tot, 0.0), axis=0)
                    totals = jnp.sum(all_tot, axis=0)
                    v_init = jnp.max(jnp.where(before[..., 0], all_last,
                                               -jnp.inf), axis=0)
                    v_init = jnp.where(jnp.isfinite(v_init), v_init,
                                       jnp.inf)   # "none" sentinel
                    g, t = fn_backend(v, lfs, wws, sts, c, Lp, impurity,
                                      task, min_records, h_init=h_init,
                                      v_init=v_init, totals=totals)
                    # merge over row shards: max gain, ties -> earliest
                    # shard (the sequential scan order)
                    key = jnp.where(jnp.isfinite(g), g, -jnp.inf)
                    allg = jax.lax.all_gather(key, R)           # (W, L+1)
                    allt = jax.lax.all_gather(t, R)
                    win = jnp.argmax(allg, axis=0)
                    gsel = jnp.take_along_axis(allg, win[None], 0)[0]
                    tsel = jnp.take_along_axis(allt, win[None], 0)[0]
                    return gsel, tsel

                return jax.vmap(per_col)(sv, si, cl_t)

            return jax.vmap(per_tree)(cl, lf, ww, stt)

        sharded = _shmap(
            local, self.mesh,
            in_specs=(P(F, R), P(F, R), P(None, F, None),
                      P(None), P(None), P(None, None)),
            out_specs=(P(None, F, None), P(None, F, None)))
        return sharded(sorted_vals, sorted_idx, cand, leaf_of, w, stats)


# ---------------------------------------------------------------------------
# Histogram engine: psum of (bins × stats) tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedHistNumeric(_MeshEngine):
    """Approximate supersplit for `split_mode="hist"` (DESIGN.md §6).

    Columns shard over `feature_axis`; ROWS — plain row order, no presorted
    state — shard over `row_axis` together with the class list / bag
    weights / stats.  Each shard scatter-adds its local per-leaf
    (bin × stat) count table and a single `psum` merges them: (L+1)·B·S
    floats per column per level, independent of n — the PLANET-style
    fixed-size merge vs the exact engine's resumable-scan all_gather.
    `row_axis=None` gives the column-sharded-only variant (no psum).
    The bucket count is read off bin_edges, so the engine always agrees
    with the TreeParams that produced the bucket state.
    """

    needs_bins = True

    def supersplits(self, inp, st, Lp, cand):
        g, t = self._search(inp.bin_of, inp.bin_edges, inp.leaf_of[None],
                            inp.w[None], inp.stats[None], cand[None], Lp,
                            st.impurity, st.task, st.min_records)
        return g[0], t[0]

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.bin_of, inp.bin_edges, inp.leaf_of, inp.w,
                            inp.stats, cand, Lp, st.impurity, st.task,
                            st.min_records)

    def __call__(self, bin_of, bin_edges, leaf_of, w, stats, cand, Lp,
                 impurity, task, min_records):
        """Legacy per-tree hist supersplit_fn signature."""
        g, t = self._search(bin_of, bin_edges, leaf_of[None], w[None],
                            stats[None], cand[None], Lp, impurity, task,
                            min_records)
        return g[0], t[0]

    def _search(self, bin_of, bin_edges, leaf_of, w, stats, cand, Lp,
                impurity, task, min_records):
        F, R = self.feature_axis, self.row_axis

        def local(bo, be, cl, lf, ww, stt):
            def per_tree(cl_t, lf_t, ww_t, st_t):
                def per_col(b, e, c):
                    table = splits.categorical_count_table(
                        b, lf_t, ww_t, st_t, Lp, e.shape[0])
                    if R is not None:
                        table = jax.lax.psum(table, R)      # the merge
                    return splits.best_numeric_split_histogram(
                        table, e, c, impurity, task, min_records)
                return jax.vmap(per_col)(bo, be, cl_t)
            return jax.vmap(per_tree)(cl, lf, ww, stt)

        sharded = _shmap(
            local, self.mesh,
            in_specs=(P(F, R), P(F, None), P(None, F, None),
                      P(None, R), P(None, R), P(None, R, None)),
            out_specs=(P(None, F, None), P(None, F, None)))
        return sharded(bin_of, bin_edges, cand, leaf_of, w, stats)


# ---------------------------------------------------------------------------
# Categorical engine: psum of (category × stats) tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedCategorical(_MeshEngine):
    """Exact categorical table engine under the mesh: the paper's
    'attribute value × class' count tables are built per row shard and
    merged by ONE psum of (L+1)·V·S floats per column (categorical tables
    are order-free, so the merge is exact); the Breiman-ordered prefix-cut
    scoring then runs replicated per column owner.  Requires m_cat
    divisible by the feature-axis size (pad columns or keep the local
    engine otherwise — `make_plan` defaults to local categoricals)."""

    kind = "categorical"

    def supersplits(self, inp, st, Lp, cand):
        g, m = self._search(inp.cat.T, inp.leaf_of[None], inp.w[None],
                            inp.stats[None], cand[None], Lp, st.max_arity,
                            st.impurity, st.task, st.min_records)
        return g[0], m[0]

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.cat.T, inp.leaf_of, inp.w, inp.stats, cand,
                            Lp, st.max_arity, st.impurity, st.task,
                            st.min_records)

    def _search(self, cat_cols, leaf_of, w, stats, cand, Lp, max_arity,
                impurity, task, min_records):
        F, R = self.feature_axis, self.row_axis

        def local(xc, cl, lf, ww, stt):
            def per_tree(cl_t, lf_t, ww_t, st_t):
                def per_col(x, c):
                    table = splits.categorical_count_table(
                        x, lf_t, ww_t, st_t, Lp, max_arity)
                    if R is not None:
                        table = jax.lax.psum(table, R)
                    return splits.best_categorical_split_from_table(
                        table, c, impurity, task, min_records)
                return jax.vmap(per_col)(xc, cl_t)
            return jax.vmap(per_tree)(cl, lf, ww, stt)

        sharded = _shmap(
            local, self.mesh,
            in_specs=(P(F, R), P(None, F, None), P(None, R), P(None, R),
                      P(None, R, None)),
            out_specs=(P(None, F, None), P(None, F, None, None)))
        return sharded(cat_cols, cand, leaf_of, w, stats)
