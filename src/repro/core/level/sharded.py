"""Mesh-sharded SplitEngines (paper §2 worker topology → shard_map).

Topology mapping (DESIGN.md §5):

  * `feature_axis` ("model") = the splitters: feature columns are sharded
    over it, each device searching optimal splits only on its own columns
    (paper: "each worker is assigned to a subset of columns ... read
    sequentially").
  * `row_axis` ("data") = row shards.  For the exact engine these are
    range-partitions of the PRESORTED order (beyond-paper 2-D extension):
    shard r of a column holds sorted rows [r·n/w, (r+1)·n/w), and exactness
    is preserved by resuming each shard's pass from the previous shard's
    histogram/value state — an all_gather of (ℓ+1)·S floats per leaf
    histogram, tiny compared to the data.  For the histogram and
    categorical engines rows shard in PLAIN row order and a single `psum`
    merges the fixed-size (ℓ+1)·V·S count tables — the paper's
    network-complexity contrast, executable side by side.

Every engine here is `batch_native`: the fused level step calls it ONCE
per depth with a leading tree axis T, and the shard_map body vmaps over
trees INSIDE the mesh program.  Sharded training therefore inherits the
multi-tree batch axis, the early-finish masking, and the device-resident
pruning of the batched builder with no special-cased host loop — D (not
T·D) device dispatches per forest, same as local training.

Engines also implement `__call__` with the original `supersplit_fn`
signatures, so existing call sites (launch/dryrun.py, older tests) keep
working unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import splits
from repro.core.level.engines import SplitEngine, _expand_subtracted

try:  # jax>=0.6 stable name, fall back to experimental
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") else _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def _shmap(f, mesh, in_specs, out_specs):
    try:    # jax>=0.6 spells the replication check "check_vma"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # jax 0.4.x spells it "check_rep"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class _MeshEngine(SplitEngine):
    mesh: object = None         # jax.sharding.Mesh (hashable)
    feature_axis: str = "model"
    row_axis: Optional[str] = "data"

    batch_native = True

    def row_shards(self) -> int:
        if self.row_axis is None:
            return 1
        return int(self.mesh.shape[self.row_axis])


# ---------------------------------------------------------------------------
# Exact numeric engine: columns over "model", presorted rows over "data"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedExactNumeric(_MeshEngine):
    """Exact supersplit with columns and (optionally) presorted rows sharded.

    Per column: each row shard computes (a) its local per-leaf stat totals
    and last in-bag value, (b) all_gathers them over `row_axis` (payload
    (L+1)·S floats — independent of n), (c) forms the exclusive shard
    prefix (h_init, v_init) and GLOBAL totals, and (d) runs the exact
    backend on its local slice resuming from that state.  Partial bests
    merge with a first-max over shards, matching the sequential scan
    order's tie-breaking.  `row_axis=None` is the paper's column-only
    splitter layout (rows replicated, no collectives).
    """
    backend: str = "segment"

    needs_sorted = True

    def supersplits(self, inp, st, Lp, cand):
        g, t = self._search(inp.sorted_vals, inp.sorted_idx,
                            inp.leaf_of[None], inp.w[None], inp.stats[None],
                            cand[None], Lp, st.impurity, st.task,
                            st.min_records)
        return g[0], t[0]

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.sorted_vals, inp.sorted_idx, inp.leaf_of,
                            inp.w, inp.stats, cand, Lp, st.impurity,
                            st.task, st.min_records)

    def __call__(self, sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                 Lp, impurity, task, min_records):
        """Legacy per-tree supersplit_fn signature."""
        g, t = self._search(sorted_vals, sorted_idx, leaf_of[None], w[None],
                            stats[None], cand[None], Lp, impurity, task,
                            min_records)
        return g[0], t[0]

    def _search(self, sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                Lp, impurity, task, min_records):
        F, R = self.feature_axis, self.row_axis
        fn_backend = splits.NUMERIC_BACKENDS[self.backend]

        def local(sv, si, cl, lf, ww, stt):
            # sv/si: (m_loc, n_loc) shard of the presorted order (GLOBAL
            # row ids); cl (T, m_loc, L+1); lf/ww (T, n); stt (T, n, S)
            # replicated — the paper's splitter memory layout ("Sliq/R and
            # DRF duplicate the class list in each worker").
            def per_tree(cl_t, lf_t, ww_t, st_t):
                def per_col(v, s, c):
                    lfs, wws, sts = lf_t[s], ww_t[s], st_t[s]
                    if R is None:
                        return fn_backend(v, lfs, wws, sts, c, Lp, impurity,
                                          task, min_records)
                    inbag = (wws > 0) & (lfs > 0)
                    contrib = jnp.where(inbag[:, None], sts, 0.0)
                    loc_tot = jax.ops.segment_sum(contrib, lfs,
                                                  num_segments=Lp + 1)
                    loc_last = jax.ops.segment_max(
                        jnp.where(inbag, v, -jnp.inf), lfs,
                        num_segments=Lp + 1)
                    all_tot = jax.lax.all_gather(loc_tot, R)   # (W, L+1, S)
                    all_last = jax.lax.all_gather(loc_last, R)  # (W, L+1)
                    r = jax.lax.axis_index(R)
                    W = all_tot.shape[0]
                    before = (jnp.arange(W) < r)[:, None, None]
                    h_init = jnp.sum(jnp.where(before, all_tot, 0.0), axis=0)
                    totals = jnp.sum(all_tot, axis=0)
                    v_init = jnp.max(jnp.where(before[..., 0], all_last,
                                               -jnp.inf), axis=0)
                    v_init = jnp.where(jnp.isfinite(v_init), v_init,
                                       jnp.inf)   # "none" sentinel
                    g, t = fn_backend(v, lfs, wws, sts, c, Lp, impurity,
                                      task, min_records, h_init=h_init,
                                      v_init=v_init, totals=totals)
                    # merge over row shards: max gain, ties -> earliest
                    # shard (the sequential scan order)
                    key = jnp.where(jnp.isfinite(g), g, -jnp.inf)
                    allg = jax.lax.all_gather(key, R)           # (W, L+1)
                    allt = jax.lax.all_gather(t, R)
                    win = jnp.argmax(allg, axis=0)
                    gsel = jnp.take_along_axis(allg, win[None], 0)[0]
                    tsel = jnp.take_along_axis(allt, win[None], 0)[0]
                    return gsel, tsel

                return jax.vmap(per_col)(sv, si, cl_t)

            return jax.vmap(per_tree)(cl, lf, ww, stt)

        sharded = _shmap(
            local, self.mesh,
            in_specs=(P(F, R), P(F, R), P(None, F, None),
                      P(None), P(None), P(None, None)),
            out_specs=(P(None, F, None), P(None, F, None)))
        return sharded(sorted_vals, sorted_idx, cand, leaf_of, w, stats)


# ---------------------------------------------------------------------------
# Histogram engine: psum of (bins × stats) tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedHistNumeric(_MeshEngine):
    """Approximate supersplit for `split_mode="hist"` (DESIGN.md §6).

    Columns shard over `feature_axis`; ROWS — plain row order, no presorted
    state — shard over `row_axis` together with the class list / bag
    weights / stats.  Each shard builds its local per-leaf (bin × stat)
    tables for ALL its columns in one flat scatter
    (`splits.feature_count_tables`, reading only the bit-packed bin cache)
    and a single `psum` per level merges them — the PLANET-style fixed-size
    merge vs the exact engine's resumable-scan all_gather.  Under
    `st.subtract` only the packed BUILD-slot tables cross the network
    ((ℓ/2+1)·B·S floats per column, ~half the plain payload); each shard
    then derives every sibling locally as parent − sibling from the
    replicated-in-spec carried tables.  `row_axis=None` gives the
    column-sharded-only variant (no psum).  Thresholds are reported as
    BIN INDICES (`bin_cut_thresholds`), decoded on the host.
    """

    needs_bins = True
    bin_cut_thresholds = True
    carries_tables = True
    supports_stream = True

    # -- streaming (DESIGN.md §8) -------------------------------------------
    # The accumulator keeps a leading row-shard axis R so `stream_accumulate`
    # is collective-FREE: each row shard adds its local chunk tables into
    # its own accumulator slice, and the level's single psum happens once
    # in `stream_finalize` — the same one-merge-per-level network profile
    # as the in-memory engine.

    def _acc_spec(self):
        return P(self.row_axis, None, self.feature_axis, None, None, None)

    def stream_init(self, T, st, Lp):
        from jax.sharding import NamedSharding
        S = st.num_classes if st.task == "classification" else 3
        R = self.row_shards()
        zeros = jnp.zeros((R, T, st.m_num, Lp + 1, st.num_bins, S),
                          jnp.float32)
        if self.row_axis is None:
            return zeros
        return jax.device_put(zeros, NamedSharding(self.mesh,
                                                   self._acc_spec()))

    def stream_accumulate(self, acc, bins, leaf, w, stats, labels, st, Lp):
        B = st.num_bins
        if self.row_axis is None:
            return acc + jax.vmap(
                lambda lf, ww, stt: splits.feature_count_tables(
                    bins, lf, ww, stt, Lp, B))(leaf, w, stats)[None]

        def local(a, bo, lf, ww, stt):
            # a (1, T, m_loc, L+1, B, S); bo (m_loc, c_loc); lf/ww (T, c_loc)
            return a + jax.vmap(
                lambda l, x, s: splits.feature_count_tables(
                    bo, l, x, s, Lp, B))(lf, ww, stt)[None]

        F, R = self.feature_axis, self.row_axis
        return _shmap(local, self.mesh,
                      in_specs=(self._acc_spec(), P(F, R), P(None, R),
                                P(None, R), P(None, R, None)),
                      out_specs=self._acc_spec())(acc, bins, leaf, w, stats)

    def stream_finalize(self, acc):
        if self.row_axis is None:
            return acc[0]

        def merge(a):
            return jax.lax.psum(a[0], self.row_axis)

        return _shmap(merge, self.mesh, in_specs=(self._acc_spec(),),
                      out_specs=P(None, self.feature_axis, None, None,
                                  None))(acc)

    def supersplits(self, inp, st, Lp, cand):
        one = lambda x: None if x is None else x[None]
        res = self._search(inp.bin_of, one(inp.leaf_of), one(inp.w),
                           one(inp.stats), one(cand), Lp, st,
                           one(inp.prev_tables), one(inp.parent_of),
                           one(inp.sib_of), one(inp.slot_of))
        return tuple(r[0] for r in res)

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.bin_of, inp.leaf_of, inp.w, inp.stats,
                            cand, Lp, st, inp.prev_tables, inp.parent_of,
                            inp.sib_of, inp.slot_of)

    def __call__(self, bin_of, bin_edges, leaf_of, w, stats, cand, Lp,
                 impurity, task, min_records):
        """Legacy per-tree hist supersplit_fn signature (float thresholds,
        decoded here from the device-side edges for back-compat)."""
        from repro.core.level.engines import LevelStatics
        st = LevelStatics(m_num=bin_of.shape[0], m_cat=0, max_arity=1,
                          num_classes=stats.shape[-1],
                          num_bins=bin_edges.shape[-1], impurity=impurity,
                          task=task, min_records=min_records)
        g, c = self._search(bin_of, leaf_of[None], w[None], stats[None],
                            cand[None], Lp, st, None, None, None, None)
        cuts = c[0].astype(jnp.int32)
        thr = jnp.take_along_axis(bin_edges, cuts, axis=1)
        return g[0], jnp.where(jnp.isfinite(g[0]), thr, 0.0)

    def _search(self, bin_of, leaf_of, w, stats, cand, Lp, st,
                prev_tables, parent_of, sib_of, slot_of):
        F, R = self.feature_axis, self.row_axis
        B = st.num_bins
        subtract = st.subtract
        Wb = Lp // 2 + 1 if subtract else Lp + 1
        impurity, task, min_records = st.impurity, st.task, st.min_records

        def local(bo, cl, lf, ww, stt, *sub):
            # bo (m_loc, n_loc); cl (T, m_loc, L+1); lf/ww (T, n_loc);
            # stt (T, n_loc, S); sub = (prev (T, m_loc, Wprev, B, S),
            # parent/sib/slot (T, L+1)) when subtracting
            def build(lf_t, ww_t, st_t, slot_t):
                # NO row compaction here: the build-rows <= n/2 bound is
                # global, not per row shard — derive rows mask to slot 0
                ids = slot_t[lf_t] if subtract else lf_t
                return splits.feature_count_tables(bo, ids, ww_t, st_t,
                                                   Wb - 1, B)
            if subtract:
                prev, par, sib, slot = sub
                packed = jax.vmap(build)(lf, ww, stt, slot)
            else:
                packed = jax.vmap(lambda a, b, c: build(a, b, c, None))(
                    lf, ww, stt)
            if R is not None:
                # THE merge: one psum of the (T, m_loc, Wb, B, S) tables —
                # under subtraction only build slots cross the network
                packed = jax.lax.psum(packed, R)
            if subtract:
                tables = jax.vmap(
                    lambda pk, pv, pr, sb, sl:
                    _expand_subtracted(pk, pv, pr, sb, sl))(
                        packed, prev, par, sib, slot)
            else:
                tables = packed

            def score(tb_t, cl_t):
                return jax.vmap(
                    lambda tb, c: splits.best_numeric_split_histogram(
                        tb, c, impurity, task, min_records))(tb_t, cl_t)
            g, cuts = jax.vmap(score)(tables, cl)
            if st.carry_tables:
                return g, cuts, tables
            return g, cuts

        tab_spec = P(None, F, None, None, None)
        in_specs = [P(F, R), P(None, F, None), P(None, R), P(None, R),
                    P(None, R, None)]
        args = [bin_of, cand, leaf_of, w, stats]
        if subtract:
            in_specs += [tab_spec, P(None, None), P(None, None),
                         P(None, None)]
            args += [prev_tables, parent_of, sib_of, slot_of]
        out_specs = (P(None, F, None), P(None, F, None))
        if st.carry_tables:
            out_specs = out_specs + (tab_spec,)
        sharded = _shmap(local, self.mesh,
                         in_specs=tuple(in_specs), out_specs=out_specs)
        return sharded(*args)


# ---------------------------------------------------------------------------
# Categorical engine: psum of (category × stats) tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedCategorical(_MeshEngine):
    """Exact categorical table engine under the mesh: the paper's
    'attribute value × class' count tables are built per row shard and
    merged by ONE psum of (L+1)·V·S floats per column (categorical tables
    are order-free, so the merge is exact); the Breiman-ordered prefix-cut
    scoring then runs replicated per column owner.  Requires m_cat
    divisible by the feature-axis size (pad columns or keep the local
    engine otherwise — `make_plan` defaults to local categoricals)."""

    kind = "categorical"

    def supersplits(self, inp, st, Lp, cand):
        g, m = self._search(inp.cat.T, inp.leaf_of[None], inp.w[None],
                            inp.stats[None], cand[None], Lp, st.max_arity,
                            st.impurity, st.task, st.min_records)
        return g[0], m[0]

    def supersplits_batched(self, inp, st, Lp, cand):
        return self._search(inp.cat.T, inp.leaf_of, inp.w, inp.stats, cand,
                            Lp, st.max_arity, st.impurity, st.task,
                            st.min_records)

    def _search(self, cat_cols, leaf_of, w, stats, cand, Lp, max_arity,
                impurity, task, min_records):
        F, R = self.feature_axis, self.row_axis

        def local(xc, cl, lf, ww, stt):
            def per_tree(cl_t, lf_t, ww_t, st_t):
                def per_col(x, c):
                    table = splits.categorical_count_table(
                        x, lf_t, ww_t, st_t, Lp, max_arity)
                    if R is not None:
                        table = jax.lax.psum(table, R)
                    return splits.best_categorical_split_from_table(
                        table, c, impurity, task, min_records)
                return jax.vmap(per_col)(xc, cl_t)
            return jax.vmap(per_tree)(cl, lf, ww, stt)

        sharded = _shmap(
            local, self.mesh,
            in_specs=(P(F, R), P(None, F, None), P(None, R), P(None, R),
                      P(None, R, None)),
            out_specs=(P(None, F, None), P(None, F, None, None)))
        return sharded(cat_cols, cand, leaf_of, w, stats)
