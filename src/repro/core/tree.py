"""Level-by-level decision tree builder (paper Alg. 2) + flat tree arrays.

The *tree builder* is the control plane (host Python, like the paper's tree
builder workers which "do not have access to the dataset"); the per-level
supersplit search and condition evaluation are the data plane (jitted JAX,
the paper's splitters).  All nodes of a depth are split together, so the
whole dataset is scanned once per candidate feature per LEVEL — never per
node — which is the paper's central complexity win over Sprint.

Per-level network/disk accounting (paper Table 1) is recorded in
`LevelStats` by the builder: one bit per sample per level broadcast
("Dn bits in D allreduce"), the ⌈log2(ℓ+1)⌉·n class-list bits, and the
number of sequential passes over the data.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, class_list, splits


# ---------------------------------------------------------------------------
# Hyper-parameters & flat tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeParams:
    max_depth: int = 20
    min_records: float = 1.0        # paper: "minimum number of records in a leaf"
    num_candidates: Optional[int] = None  # m' (None = ceil(sqrt(m)), the paper default)
    impurity: str = "gini"          # gini | entropy | variance
    task: str = "classification"
    backend: str = "segment"        # segment | scan | kernel (Pallas)
    usb: bool = False               # unique set of bagged features per depth (§3.2)
    bagging: str = "poisson"        # poisson | multinomial | none
    leaf_pad: int = 8               # pad open-leaf count to multiples (recompile bound)
    # Sprint-style record pruning (paper §3): when the fraction of samples
    # sitting in CLOSED leaves reaches this threshold, compact the dataset
    # (drop those rows, filter the presorted order — no re-sort needed).
    # 1.0 disables it, which is the paper's Leo configuration ("this
    # operation is not triggered during the experimentation").
    prune_closed_frac: float = 1.0


@dataclasses.dataclass
class Tree:
    """Flat-array decision tree (numpy, host-side)."""
    feature: np.ndarray        # (N,) int32; -1 = leaf
    threshold: np.ndarray      # (N,) float32 (numeric nodes)
    is_cat: np.ndarray         # (N,) bool
    cat_mask: np.ndarray       # (N, max_arity) bool; True -> go LEFT
    children: np.ndarray       # (N, 2) int32 [left, right]
    value: np.ndarray          # (N, C) class distribution / (N, 1) mean
    n_node: np.ndarray         # (N,) in-bag weight reaching the node
    gain: np.ndarray           # (N,) split gain (0 for leaves)
    depth: np.ndarray          # (N,) int32
    m_num: int
    task: str

    @property
    def num_nodes(self) -> int:
        return len(self.feature)

    @property
    def num_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth_reached(self) -> int:
        return int(self.depth.max()) if self.num_nodes else 0

    def node_density(self) -> float:
        """Paper §5: #leaves / 2^D for the deepest depth D."""
        d = self.max_depth_reached
        return self.num_leaves / float(2 ** d) if d else 1.0

    def sample_density(self) -> float:
        """Paper §5: fraction of in-bag weight reaching depth-D leaves."""
        d = self.max_depth_reached
        leaves = self.feature < 0
        bottom = leaves & (self.depth == d)
        tot = self.n_node[leaves].sum()
        return float(self.n_node[bottom].sum() / tot) if tot > 0 else 0.0

    def predict_raw(self, num: jnp.ndarray, cat: jnp.ndarray) -> jnp.ndarray:
        """(B, C) distributions / (B, 1) means."""
        return _predict_jit(
            jnp.asarray(self.feature), jnp.asarray(self.threshold),
            jnp.asarray(self.is_cat), jnp.asarray(self.cat_mask),
            jnp.asarray(self.children), jnp.asarray(self.value),
            num, cat, self.m_num, int(self.depth.max()) + 1)


@dataclasses.dataclass
class LevelStats:
    """Per-level complexity counters (benchmarks/table1)."""
    depth: int
    open_leaves: int
    network_bits_bitmap: int     # the 1-bit-per-sample broadcast
    network_bits_supersplit: int # partial supersplit payloads (tiny)
    class_list_bits: int         # n * ceil(log2(l+1))
    feature_passes: int          # sequential passes over candidate columns
    rows_scanned: int


# ---------------------------------------------------------------------------
# Jitted per-level pieces
# ---------------------------------------------------------------------------

def _pad_leaves(L: int, pad: int) -> int:
    """Pad to a power of two (recompilation count is O(log leaves))."""
    return max(pad, 1 << (L - 1).bit_length())


@jax.jit
def _gather_sorted_level(sorted_idx, leaf_of, w, stats):
    """Per-column gathers of the level state in presorted order."""
    return leaf_of[sorted_idx], w[sorted_idx], stats[sorted_idx]


def _numeric_supersplits(backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                         cand, Lp, impurity, task, min_records):
    """vmap the chosen exact backend over numerical columns.

    sorted_vals/sorted_idx: (m_num, n); cand: (m_num, Lp+1).
    Returns gains (m_num, Lp+1), thresholds (m_num, Lp+1).
    """
    fn = splits.NUMERIC_BACKENDS[backend]
    def per_col(v, si, cl):
        lf, ww, st = _gather_sorted_level(si, leaf_of, w, stats)
        return fn(v, lf, ww, st, cl, Lp, impurity, task, min_records)
    return jax.vmap(per_col)(sorted_vals, sorted_idx, cand)


def _categorical_supersplits(cat_cols, leaf_of, w, stats, cand, Lp, max_arity,
                             impurity, task, min_records):
    """vmap exact categorical search over columns padded to max_arity."""
    def per_col(x, cl):
        return splits.best_categorical_split(
            x, leaf_of, w, stats, cl, Lp, max_arity, impurity, task, min_records)
    return jax.vmap(per_col)(cat_cols, cand)


@functools.partial(jax.jit, static_argnames=("m_num",))
def _evaluate_conditions(num, cat, leaf_of, feat_of_leaf, thr_of_leaf,
                         iscat_of_leaf, mask_of_leaf, m_num):
    """Alg. 2 step 5: evaluate the winning condition of each sample's leaf.

    Returns bits (n,) bool — True = LEFT.  In the distributed engine this is
    the 1-bit-per-sample payload that gets allreduced (see distributed.py).
    """
    f = feat_of_leaf[leaf_of]                                   # (n,)
    jn = jnp.clip(f, 0, max(m_num - 1, 0))
    jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
    xnum = jnp.take_along_axis(num, jn[:, None], axis=1)[:, 0] if num.size else jnp.zeros_like(leaf_of, jnp.float32)
    xcat = jnp.take_along_axis(cat, jc[:, None], axis=1)[:, 0] if cat.size else jnp.zeros_like(leaf_of)
    num_bit = xnum <= thr_of_leaf[leaf_of]
    cat_bit = mask_of_leaf[leaf_of, xcat]
    return jnp.where(iscat_of_leaf[leaf_of], cat_bit, num_bit)


@functools.partial(jax.jit, static_argnames=("Lp",))
def _leaf_totals(leaf_of, stats, w, Lp):
    inbag = (w > 0) & (leaf_of > 0)
    return jax.ops.segment_sum(jnp.where(inbag[:, None], stats, 0.0),
                               leaf_of, num_segments=Lp + 1)


@jax.jit
def _reassign(leaf_of, bits, new_left, new_right):
    """Alg. 2 step 6: map samples to child leaf ids (0 if child closed)."""
    child = jnp.where(bits, new_left[leaf_of], new_right[leaf_of])
    return jnp.where(leaf_of > 0, child, 0)


# ---------------------------------------------------------------------------
# The tree builder (Alg. 2)
# ---------------------------------------------------------------------------

def build_tree(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_idx: int,
    collect_stats: bool = False,
    supersplit_fn=None,
) -> tuple[Tree, list[LevelStats]]:
    """Train one tree, depth level by depth level.

    `supersplit_fn`, when given, replaces the local numeric supersplit search
    (used by distributed.py to run it under shard_map on the mesh).
    """
    n = int(labels.shape[0])
    m_num = int(sorted_vals.shape[0]) if sorted_vals.size else 0
    m_cat = len(arities)
    m = m_num + m_cat
    max_arity = max(arities) if arities else 1
    task = params.task
    m_prime = params.num_candidates or max(1, math.isqrt(m) + (0 if math.isqrt(m) ** 2 == m else 1))

    w = bagging.bag_counts(seed, tree_idx, n, params.bagging)
    stats = splits.row_stats(labels, w, num_classes, task)
    s_dim = stats.shape[-1]
    cnt = splits.count_fn(task)
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)

    # node storage (host lists)
    feature, threshold, is_cat_l, cat_mask_l = [], [], [], []
    children, value, n_node, gain_l, depth_l = [], [], [], [], []

    def new_node(depth):
        feature.append(-1); threshold.append(0.0); is_cat_l.append(False)
        cat_mask_l.append(None); children.append([-1, -1])
        value.append(np.zeros(max(num_classes, 2) if task == "classification" else 1,
                              np.float32))
        n_node.append(0.0); gain_l.append(0.0); depth_l.append(depth)
        return len(feature) - 1

    root = new_node(0)
    open_nodes = [root]                       # leaf id h (1-based) -> node id
    leaf_of = jnp.ones((n,), jnp.int32)       # all samples at the root
    stats_log: list[LevelStats] = []

    for depth in range(params.max_depth + 1):
        L = len(open_nodes)
        if L == 0:
            break
        Lp = _pad_leaves(L, params.leaf_pad)

        # leaf totals -> node values & forced closes
        totals = np.asarray(_leaf_totals(leaf_of, stats, w, Lp))  # (Lp+1, S)
        counts = np.asarray(cnt(jnp.asarray(totals)))
        for h, node in enumerate(open_nodes, start=1):
            n_node[node] = float(counts[h])
            if task == "classification":
                tot = max(counts[h], 1e-12)
                value[node] = (totals[h] / tot).astype(np.float32)
            else:
                wsum = max(totals[h, 0], 1e-12)
                value[node] = np.array([totals[h, 1] / wsum], np.float32)

        at_max_depth = depth >= params.max_depth
        splittable = np.array(
            [counts[h] >= 2 * params.min_records and not at_max_depth
             for h in range(1, L + 1)] + [False] * (Lp - L))
        if not splittable.any():
            break

        # Alg. 2 step 3: query the splitters for the optimal supersplit
        cand = bagging.candidate_features(fkey, depth, Lp, m, m_prime, params.usb)
        cand = cand & jnp.asarray(splittable)[:, None]
        cand_p = jnp.concatenate([jnp.zeros((1, m), bool), cand], 0)  # leaf 0 = closed

        all_gains = np.full((m, Lp + 1), -np.inf, np.float32)
        all_thr = np.zeros((m, Lp + 1), np.float32)
        all_masks = None
        if m_num:
            if supersplit_fn is not None:
                g, t = supersplit_fn(
                    sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            elif params.backend == "kernel":
                from repro.kernels import ops as kops
                g, t = kops.split_scan_supersplit(
                    sorted_vals, sorted_idx, leaf_of, w, labels,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            else:
                g, t = _numeric_supersplits(
                    params.backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            all_gains[:m_num], all_thr[:m_num] = np.asarray(g), np.asarray(t)
        if m_cat:
            g, masks = _categorical_supersplits(
                cat.T, leaf_of, w, stats, cand_p[:, m_num:].T, Lp, max_arity,
                params.impurity, task, params.min_records)
            all_gains[m_num:] = np.asarray(g)
            all_masks = np.asarray(masks)                    # (m_cat, Lp+1, V)

        # tree builder merges partial supersplits (Alg. 2 step 3, final argmax)
        best_feat = all_gains.argmax(axis=0)                 # (Lp+1,)
        best_gain = all_gains[best_feat, np.arange(Lp + 1)]

        # Alg. 2 step 8: close leaves with no good condition
        feat_of_leaf = np.zeros(Lp + 1, np.int32)
        thr_of_leaf = np.zeros(Lp + 1, np.float32)
        iscat_of_leaf = np.zeros(Lp + 1, bool)
        mask_of_leaf = np.zeros((Lp + 1, max_arity), bool)
        new_left = np.zeros(Lp + 1, np.int32)
        new_right = np.zeros(Lp + 1, np.int32)
        next_open: list[int] = []
        any_split = False
        for h in range(1, L + 1):
            node = open_nodes[h - 1]
            if not splittable[h - 1] or not np.isfinite(best_gain[h]) or best_gain[h] <= 1e-9:
                continue
            j = int(best_feat[h])
            any_split = True
            feature[node] = j
            gain_l[node] = float(best_gain[h])
            feat_of_leaf[h] = j
            if j < m_num:
                threshold[node] = float(all_thr[j, h])
                thr_of_leaf[h] = all_thr[j, h]
            else:
                is_cat_l[node] = True
                iscat_of_leaf[h] = True
                cm = all_masks[j - m_num, h]
                cat_mask_l[node] = cm.copy()
                mask_of_leaf[h] = cm
            lc, rc = new_node(depth + 1), new_node(depth + 1)
            children[node] = [lc, rc]
            next_open.extend([lc, rc])
            new_left[h] = len(next_open) - 1               # 1-based ids below
            new_right[h] = len(next_open)

        if collect_stats:
            open_w = float(counts[1:L + 1].sum())
            stats_log.append(LevelStats(
                depth=depth, open_leaves=L,
                network_bits_bitmap=int(open_w),
                network_bits_supersplit=int(m * (Lp + 1) * 64),
                class_list_bits=class_list.storage_bits(n, L),
                feature_passes=int(min(m_prime * (1 if params.usb else L), m)),
                rows_scanned=n * min(m_prime * (1 if params.usb else L), m)))

        if not any_split:
            break

        # Alg. 2 steps 5-7: evaluate conditions (1 bit/sample) and reassign
        bits = _evaluate_conditions(
            num, cat, leaf_of, jnp.asarray(feat_of_leaf), jnp.asarray(thr_of_leaf),
            jnp.asarray(iscat_of_leaf), jnp.asarray(mask_of_leaf), m_num)
        leaf_of = _reassign(leaf_of, bits, jnp.asarray(new_left), jnp.asarray(new_right))
        open_nodes = next_open

        # Sprint-style pruning switch (paper §3): compact rows in closed
        # leaves once they dominate.  The presorted order is FILTERED, not
        # re-sorted (stability preserves it), so the one-time cost is one
        # pass — the trade-off rule the paper describes.
        if params.prune_closed_frac < 1.0 and n > 0:
            lf_np = np.asarray(leaf_of)
            keep = lf_np > 0
            frac_closed = 1.0 - keep.mean()
            if frac_closed >= params.prune_closed_frac and keep.any() \
                    and keep.sum() < n:
                remap = np.cumsum(keep) - 1
                idx_np = np.asarray(sorted_idx)
                vals_np = np.asarray(sorted_vals)
                kept_cols = keep[idx_np]                      # (m_num, n)
                n_new = int(keep.sum())
                new_idx = np.empty((m_num, n_new), np.int32)
                new_vals = np.empty((m_num, n_new), np.float32)
                for j in range(m_num):
                    sel = kept_cols[j]
                    new_idx[j] = remap[idx_np[j][sel]]
                    new_vals[j] = vals_np[j][sel]
                sorted_idx = jnp.asarray(new_idx)
                sorted_vals = jnp.asarray(new_vals)
                num = num[jnp.asarray(keep)] if num.size else num
                cat = cat[jnp.asarray(keep)] if cat.size else cat
                stats = stats[jnp.asarray(keep)]
                w = w[jnp.asarray(keep)]
                labels = labels[jnp.asarray(keep)]
                leaf_of = jnp.asarray(lf_np[keep])
                n = n_new

    N = len(feature)
    cat_mask_arr = np.zeros((N, max_arity), bool)
    for i, cm in enumerate(cat_mask_l):
        if cm is not None:
            cat_mask_arr[i, :len(cm)] = cm
    tree = Tree(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float32),
        is_cat=np.asarray(is_cat_l, bool),
        cat_mask=cat_mask_arr,
        children=np.asarray(children, np.int32),
        value=np.stack(value).astype(np.float32),
        n_node=np.asarray(n_node, np.float32),
        gain=np.asarray(gain_l, np.float32),
        depth=np.asarray(depth_l, np.int32),
        m_num=m_num, task=task)
    return tree, stats_log


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_num", "iters"))
def _predict_jit(feature, threshold, is_cat, cat_mask, children, value,
                 num, cat, m_num, iters):
    B = num.shape[0] if num.size else cat.shape[0]
    node = jnp.zeros((B,), jnp.int32)

    def body(_, node):
        f = feature[node]
        leaf = f < 0
        jn = jnp.clip(f, 0, max(m_num - 1, 0))
        jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
        xnum = (jnp.take_along_axis(num, jn[:, None], 1)[:, 0]
                if num.size else jnp.zeros((B,), jnp.float32))
        xcat = (jnp.take_along_axis(cat, jc[:, None], 1)[:, 0]
                if cat.size else jnp.zeros((B,), jnp.int32))
        go_left = jnp.where(is_cat[node], cat_mask[node, xcat],
                            xnum <= threshold[node])
        nxt = jnp.where(go_left, children[node, 0], children[node, 1])
        return jnp.where(leaf, node, nxt)

    node = jax.lax.fori_loop(0, iters, body, node)
    return value[node]
