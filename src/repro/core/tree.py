"""Level-by-level decision tree builders (paper Alg. 2) + flat tree arrays.

The *tree builder* is the control plane (host Python, like the paper's tree
builder workers which "do not have access to the dataset"); the per-level
supersplit search and condition evaluation are the data plane (jitted JAX,
the paper's splitters).  All nodes of a depth are split together, so the
whole dataset is scanned once per candidate feature per LEVEL — never per
node — which is the paper's central complexity win over Sprint.

This module is the HOST DRIVER layer only.  The data plane lives in
`repro.core.level`: a `LevelPlan` composes a numeric and a categorical
`SplitEngine` (exact / histogram × local / mesh-sharded) into ONE fused
jitted program per depth level (DESIGN.md §7).  The drivers here own the
flat-tree bookkeeping (`_NodeAccum`), the frontier padding, the Sprint
pruning switch, and the per-level host protocol:

  * `build_tree` — one tree, one fused program per depth
    (`level.plan._fused_level_step`); the fallback for legacy
    `supersplit_fn` closures, otherwise prefer `build_forest`.
  * `build_forest` — a whole BATCH of trees per level program (vmap /
    lax.map over a leading tree axis, T·D → D dispatches, DESIGN.md §3),
    bit-identical per tree.  The host loop is PIPELINED: each level's
    Python bookkeeping (`_grow_level`, node values) is deferred until
    after the NEXT level's program has been dispatched, so host work
    overlaps device compute; transfers start with `copy_to_host_async`.
  * `build_tree_reference` (repro.core.reference) — the pre-fusion seed
    builder, kept as the executable specification the fused builders must
    reproduce bit-for-bit.

Per-level network/disk accounting (paper Table 1) is recorded in
`LevelStats` by the builders: one bit per sample per level broadcast
("Dn bits in D allreduce"), the ⌈log2(ℓ+1)⌉·n class-list bits, and the
number of sequential passes over the data.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, class_list, presort, pruning, splits
from repro.core.level.engines import LegacyFn, SplitEngine
from repro.core.level.plan import (_BATCH_STEP_CALLS, _BATCH_STEP_TRACES,
                                   _BATCH_VMAP_ELEMS_DEFAULT, _STEP_CALLS,
                                   _fused_level_step,
                                   _fused_level_step_batched, _leaf_totals,
                                   _pad_leaves, make_plan)

# Tuning knob read (late-bound) by level.plan: above this many row-state
# elements (T·m_num·n) the batched level step switches from vmap to
# lax.map over trees — see `level.plan._fused_level_step_batched`.
_BATCH_VMAP_ELEMS = _BATCH_VMAP_ELEMS_DEFAULT


# ---------------------------------------------------------------------------
# Hyper-parameters & flat tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeParams:
    max_depth: int = 20
    min_records: float = 1.0        # paper: "minimum number of records in a leaf"
    num_candidates: Optional[int] = None  # m' (None = ceil(sqrt(m)), the paper default)
    impurity: str = "gini"          # gini | entropy | variance
    task: str = "classification"
    backend: str = "segment"        # segment | scan | kernel (Pallas)
    # exact = the paper's midpoint-exhaustive search (default); hist = the
    # PLANET-style contrast baseline: numeric columns quantized once into
    # <= num_bins buckets, splits scored on bucket boundaries only, from
    # per-leaf (bin × class) count tables (DESIGN.md §6)
    split_mode: str = "exact"       # exact | hist
    num_bins: int = 255             # histogram-mode bucket budget per column
    # histogram subtraction (DESIGN.md §6): carry each level's per-leaf
    # tables and build only the SMALLER child of every split, deriving the
    # sibling as parent − sibling — ~half the table-build work per level
    # and, sharded, ~half the psum payload.  Classification only (integer
    # tables make the subtraction exact; regression always rebuilds
    # plain); results are bit-identical either way, so this is purely a
    # perf knob.
    hist_subtract: bool = True
    usb: bool = False               # unique set of bagged features per depth (§3.2)
    bagging: str = "poisson"        # poisson | multinomial | none
    leaf_pad: int = 8               # pad open-leaf count to multiples (recompile bound)
    # Sprint-style record pruning (paper §3): when the fraction of samples
    # sitting in CLOSED leaves reaches this threshold, compact the dataset
    # (drop those rows, filter the presorted order — no re-sort needed).
    # 1.0 disables it, which is the paper's Leo configuration ("this
    # operation is not triggered during the experimentation").
    prune_closed_frac: float = 1.0


@dataclasses.dataclass
class Tree:
    """Flat-array decision tree (numpy, host-side)."""
    feature: np.ndarray        # (N,) int32; -1 = leaf
    threshold: np.ndarray      # (N,) float32 (numeric nodes)
    is_cat: np.ndarray         # (N,) bool
    cat_mask: np.ndarray       # (N, max_arity) bool; True -> go LEFT
    children: np.ndarray       # (N, 2) int32 [left, right]
    value: np.ndarray          # (N, C) class distribution / (N, 1) mean
    n_node: np.ndarray         # (N,) in-bag weight reaching the node
    gain: np.ndarray           # (N,) split gain (0 for leaves)
    depth: np.ndarray          # (N,) int32
    m_num: int
    task: str

    @property
    def num_nodes(self) -> int:
        return len(self.feature)

    @property
    def num_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth_reached(self) -> int:
        return int(self.depth.max()) if self.num_nodes else 0

    def node_density(self) -> float:
        """Paper §5: #leaves / 2^D for the deepest depth D."""
        d = self.max_depth_reached
        return self.num_leaves / float(2 ** d) if d else 1.0

    def sample_density(self) -> float:
        """Paper §5: fraction of in-bag weight reaching depth-D leaves."""
        d = self.max_depth_reached
        leaves = self.feature < 0
        bottom = leaves & (self.depth == d)
        tot = self.n_node[leaves].sum()
        return float(self.n_node[bottom].sum() / tot) if tot > 0 else 0.0

    def predict_raw(self, num: jnp.ndarray, cat: jnp.ndarray) -> jnp.ndarray:
        """(B, C) distributions / (B, 1) means."""
        return _predict_jit(
            jnp.asarray(self.feature), jnp.asarray(self.threshold),
            jnp.asarray(self.is_cat), jnp.asarray(self.cat_mask),
            jnp.asarray(self.children), jnp.asarray(self.value),
            num, cat, self.m_num, int(self.depth.max()) + 1)


@dataclasses.dataclass
class LevelStats:
    """Per-level complexity counters (benchmarks/table1)."""
    depth: int
    open_leaves: int
    network_bits_bitmap: int     # the 1-bit-per-sample broadcast
    network_bits_supersplit: int # partial supersplit payloads (tiny)
    class_list_bits: int         # n * ceil(log2(l+1))
    feature_passes: int          # sequential passes over candidate columns
    rows_scanned: int
    # hist mode: bytes of the per-level merged table payload — exactly
    # what ShardedHistNumeric psums (m·width·B·S f32); under subtraction
    # only the packed build slots (width Lp//2+1 vs Lp+1) cross the
    # network, which is the ~2x payload cut benchmarks/run.py hist records
    hist_table_bytes: int = 0


# ---------------------------------------------------------------------------
# Setup helpers shared by the drivers
# ---------------------------------------------------------------------------

def _tree_setup(sorted_vals, arities, labels, params):
    if params.split_mode not in ("exact", "hist"):
        raise ValueError(f"unknown split_mode {params.split_mode!r} "
                         "(expected 'exact' or 'hist')")
    if params.split_mode == "hist" and params.num_bins < 2:
        raise ValueError("hist mode needs num_bins >= 2")
    n = int(labels.shape[0])
    m_num = int(sorted_vals.shape[0]) if sorted_vals.size else 0
    m_cat = len(arities)
    m = m_num + m_cat
    max_arity = max(arities) if arities else 1
    m_prime = params.num_candidates or max(
        1, math.isqrt(m) + (0 if math.isqrt(m) ** 2 == m else 1))
    return n, m_num, m_cat, m, max_arity, m_prime


def _hist_state(num, sorted_vals, params, m_num, bin_of, bin_edges):
    """Resolve the hist-mode bucket state (zero-size dummies in exact mode).

    When the caller (RandomForest/GBTModel.fit) did not precompute the
    quantization, derive it here from the presorted values — once per tree
    build, shared by every level.  Pre-quantized state is VALIDATED
    against `params`: a bin-count or shape disagreement used to be
    silently ignored (the engines read whatever bucket ids they were
    handed) and now raises at fit time.
    """
    if params.split_mode == "hist" and m_num:
        if bin_of is None:
            bin_of, bin_edges = presort.quantize(num, sorted_vals,
                                                 params.num_bins)
        if bin_edges is None:
            raise ValueError("pre-quantized bin_of needs its bin_edges")
        if tuple(bin_edges.shape) != (m_num, params.num_bins):
            raise ValueError(
                f"pre-quantized bucket state disagrees with TreeParams: "
                f"bin_edges shape {tuple(bin_edges.shape)} but the fit has "
                f"m_num={m_num} numeric columns and num_bins="
                f"{params.num_bins} — re-quantize the dataset (e.g. "
                f"TabularDataset.quantize(num_bins={params.num_bins})) or "
                f"set TreeParams(num_bins={bin_edges.shape[-1]})")
        if (tuple(bin_of.shape)[0] != m_num
                or bin_of.shape[-1] != num.shape[0]):
            raise ValueError(
                f"pre-quantized bin_of shape {tuple(bin_of.shape)} does "
                f"not match the dataset ((m_num, n) = "
                f"({m_num}, {num.shape[0]}))")
        if not jnp.issubdtype(bin_of.dtype, jnp.integer):
            raise ValueError(f"bin_of must be integer bucket ids, got "
                             f"dtype {bin_of.dtype}")
        if np.iinfo(np.dtype(bin_of.dtype)).max < params.num_bins - 1:
            raise ValueError(
                f"bin_of dtype {bin_of.dtype} cannot hold num_bins="
                f"{params.num_bins} bucket ids (expected "
                f"{np.dtype(presort.bin_dtype(params.num_bins)).name})")
        return bin_of, bin_edges
    return jnp.zeros((0, 0), presort.bin_dtype(params.num_bins)), \
        jnp.zeros((0, 0), jnp.float32)


def _resolve_engines(params, supersplit_fn, engine, cat_engine):
    """Back-compat: a bare `supersplit_fn` closure wraps into a LegacyFn
    engine; a SplitEngine passed as `supersplit_fn` IS the engine."""
    if supersplit_fn is not None:
        if engine is not None:
            raise ValueError(
                "pass either engine= (a SplitEngine) or supersplit_fn=, "
                "not both — one of them would be silently ignored")
        if isinstance(supersplit_fn, SplitEngine):
            engine = supersplit_fn
        else:
            engine = LegacyFn(fn=supersplit_fn,
                              hist=params.split_mode == "hist")
    return engine, cat_engine


def _make_plan(params, *, sorted_vals, arities, labels, num_classes,
               supersplit_fn=None, engine=None, cat_engine=None):
    n, m_num, m_cat, m, max_arity, m_prime = _tree_setup(
        sorted_vals, arities, labels, params)
    engine, cat_engine = _resolve_engines(params, supersplit_fn, engine,
                                          cat_engine)
    plan = make_plan(params, m_num=m_num, m_cat=m_cat, max_arity=max_arity,
                     num_classes=num_classes, m_prime=m_prime,
                     engine=engine, cat_engine=cat_engine)
    return plan, (n, m_num, m_cat, m, max_arity, m_prime)


def _zeros_unless(cond, arr, dtype):
    return arr if cond else jnp.zeros((0, 0), dtype)


# ---------------------------------------------------------------------------
# Host-side flat-tree bookkeeping (Alg. 2 step 8)
# ---------------------------------------------------------------------------

class _NodeAccum:
    """Host-side flat-tree accumulator (Alg. 2 step 8 bookkeeping).

    One per tree; the builders append nodes level by level and
    `_assemble_tree` freezes the lists into the numpy `Tree` arrays.
    """

    def __init__(self, num_classes: int, task: str):
        self.feature: list = []
        self.threshold: list = []
        self.is_cat: list = []
        self.cat_mask: list = []
        self.children: list = []
        self.value: list = []
        self.n_node: list = []
        self.gain: list = []
        self.depth: list = []
        self._C = max(num_classes, 2) if task == "classification" else 1

    def new_node(self, depth: int) -> int:
        self.feature.append(-1)
        self.threshold.append(0.0)
        self.is_cat.append(False)
        self.cat_mask.append(None)
        self.children.append([-1, -1])
        self.value.append(np.zeros(self._C, np.float32))
        self.n_node.append(0.0)
        self.gain.append(0.0)
        self.depth.append(depth)
        return len(self.feature) - 1

    def set_value(self, node: int, totals_row: np.ndarray, count: float,
                  task: str) -> None:
        """Node value from its leaf-totals row (distribution / mean)."""
        self.n_node[node] = float(count)
        if task == "classification":
            tot = max(count, 1e-12)
            self.value[node] = (totals_row / tot).astype(np.float32)
        else:
            wsum = max(totals_row[0], 1e-12)
            self.value[node] = np.array([totals_row[1] / wsum], np.float32)


def _grow_level(acc: _NodeAccum, open_nodes: list, host: dict, L: int,
                m_num: int, depth: int, edges_np=None) -> tuple[list, bool]:
    """Alg. 2 step 8 for ONE tree: grow the flat tree from a level struct.

    `host` holds the fetched per-leaf arrays of one tree (best_feat /
    best_gain / thr / mask / will_split, each (Lp+1,)-indexed by leaf id).
    Shared by `build_tree` and `build_forest` so their bookkeeping cannot
    drift.  Returns (next level's open node ids, whether any leaf split).

    `edges_np` ((m_num, B) numpy) is the hist fast path's HOST-side
    threshold decode table: the level program reports the winning BIN
    INDEX (the float edges never ride to device, DESIGN.md §6), and the
    recorded node threshold is `edges[col, cut]` — the same float the old
    device-side decode produced, so trees are unchanged.
    """
    bf, bg = host["best_feat"], host["best_gain"]
    thr, mask, ws = host["thr"], host["mask"], host["will_split"]
    next_open: list[int] = []
    any_split = False
    for h in range(1, L + 1):
        if not ws[h]:
            continue
        node = open_nodes[h - 1]
        j = int(bf[h])
        any_split = True
        acc.feature[node] = j
        acc.gain[node] = float(bg[h])
        if j < m_num:
            if edges_np is not None:
                acc.threshold[node] = float(edges_np[j, int(thr[h])])
            else:
                acc.threshold[node] = float(thr[h])
        else:
            acc.is_cat[node] = True
            acc.cat_mask[node] = mask[h].copy()
        lc, rc = acc.new_node(depth + 1), acc.new_node(depth + 1)
        acc.children[node] = [lc, rc]
        next_open.extend([lc, rc])
    return next_open, any_split


def _child_maps(ws, kc, L, Lp_next):
    """The next level's subtraction maps from this level's split bitmap.

    ws (Lp+1,) bool: which leaves split; kc (2·Lp+1,) int: row counts of
    the new child leaves (the level struct's key_counts).  Returns
    (parent_of, sib_of, slot_of), each (Lp_next+1,) int32 indexed by the
    NEW leaf ids: parent/sibling per child, and the packed build slot —
    assigned to the SMALLER child of each split (ties: left), 0 for the
    derive sibling.  Build slots stay <= Lp_next // 2, the packed table
    width the engines scatter into (build rows are therefore <= n // 2,
    the compaction bound in level/engines.py).
    """
    parent = np.zeros(Lp_next + 1, np.int32)
    sib = np.zeros(Lp_next + 1, np.int32)
    slot = np.zeros(Lp_next + 1, np.int32)
    k = 0
    for h in range(1, L + 1):
        if not ws[h]:
            continue
        k += 1
        lc, rc = 2 * k - 1, 2 * k
        parent[lc] = parent[rc] = h
        sib[lc], sib[rc] = rc, lc
        slot[lc if kc[lc] <= kc[rc] else rc] = k
    return parent, sib, slot


def _assemble_tree(acc: _NodeAccum, max_arity, m_num, task) -> Tree:
    N = len(acc.feature)
    cat_mask_arr = np.zeros((N, max_arity), bool)
    for i, cm in enumerate(acc.cat_mask):
        if cm is not None:
            cat_mask_arr[i, :len(cm)] = cm
    return Tree(
        feature=np.asarray(acc.feature, np.int32),
        threshold=np.asarray(acc.threshold, np.float32),
        is_cat=np.asarray(acc.is_cat, bool),
        cat_mask=cat_mask_arr,
        children=np.asarray(acc.children, np.int32),
        value=np.stack(acc.value).astype(np.float32),
        n_node=np.asarray(acc.n_node, np.float32),
        gain=np.asarray(acc.gain, np.float32),
        depth=np.asarray(acc.depth, np.int32),
        m_num=m_num, task=task)


# ---------------------------------------------------------------------------
# The per-tree driver (Alg. 2)
# ---------------------------------------------------------------------------

def build_tree(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_idx: int,
    collect_stats: bool = False,
    supersplit_fn=None,
    bin_of: Optional[jnp.ndarray] = None,
    bin_edges: Optional[jnp.ndarray] = None,
    engine: Optional[SplitEngine] = None,
    cat_engine: Optional[SplitEngine] = None,
) -> tuple[Tree, list[LevelStats]]:
    """Train ONE tree with one fused jitted device program per depth level.

    Args (shapes):
      num / cat:     (n, m_num) float32 / (n, m_cat) int32 raw columns.
      labels:        (n,) int32 class ids (classification) or float32
                     targets (regression).
      sorted_vals / sorted_idx: (m_num, n) per-column presorted values and
                     row indices (presort.presort_columns) — computed once
                     per forest and shared by every tree.
      arities:       per categorical column arity; categories are
                     0..arity-1, padded to max(arities) inside the step.
      num_classes:   stat width C for classification (S = C); regression
                     uses S = 3 ([w, wy, wy²]) regardless.
      params:        TreeParams; `params.backend` picks the numeric
                     supersplit engine — "segment" (default; incrementally
                     maintained (leaf, value)-sorted layout, no per-level
                     sort), "scan" (faithful Alg. 1 sequential pass) or
                     "kernel" (Pallas split_scan/cat_hist; interpret mode
                     off-TPU).
      seed/tree_idx: seeded bagging + candidate draws (paper §2.2) — all
                     randomness is a pure function of these two.
      engine/cat_engine: explicit `level.SplitEngine` overrides (e.g. the
                     mesh engines of `level.sharded`); default resolves
                     the local engine for `params.split_mode`/`backend`.
      supersplit_fn: back-compat — a SplitEngine here is used as `engine`;
                     a bare closure (the pre-engine API) wraps into
                     `level.LegacyFn` and runs per-tree, unbatched.
      bin_of/bin_edges: hist-mode bucket state ((m_num, n) int32 bucket ids
                     and (m_num, num_bins) f32 upper edges) as produced by
                     `TabularDataset.quantize`; derived here from
                     `sorted_vals` when omitted.  Ignored in exact mode.

    Produces exactly the trees of `build_tree_reference` (asserted by
    tests/test_fused_level.py) while the host does bookkeeping only: per
    level it uploads the tiny (splittable, totals) pair and fetches one
    small per-leaf struct; all row-indexed state stays on device.  To train
    many trees, prefer `build_forest`, which runs this same level plan over
    a whole tree batch.

    Returns (Tree, [LevelStats]) — the flat host-side tree and, when
    `collect_stats`, the per-level paper-Table-1 counters.
    """
    plan, (n, m_num, m_cat, m, max_arity, m_prime) = _make_plan(
        params, sorted_vals=sorted_vals, arities=arities, labels=labels,
        num_classes=num_classes, supersplit_fn=supersplit_fn, engine=engine,
        cat_engine=cat_engine)
    task = params.task
    hist = params.split_mode == "hist"
    # dataset.from_numpy keeps columns HOST-side (lazy for mmap inputs);
    # device-put once here so every level reads device arrays, not
    # re-uploaded numpy (no-op when already on device)
    num, cat, labels = jnp.asarray(num), jnp.asarray(cat), jnp.asarray(labels)
    sorted_vals = jnp.asarray(sorted_vals)
    sorted_idx = jnp.asarray(sorted_idx)
    bin_of, bin_edges = _hist_state(num, sorted_vals, params, m_num,
                                    bin_of, bin_edges)
    # hist fast path: float edges stay HOST-side, decoding the reported
    # bin cuts into node thresholds (the level program reads only the
    # bit-packed bin cache); `carries` = the subtraction recurrence is on
    carries = plan.carries_tables
    edges_np = np.asarray(bin_edges) if plan.use_bin_cuts else None

    w = bagging.bag_counts(seed, tree_idx, n, params.bagging)
    stats = splits.row_stats(labels, w, num_classes, task)
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)

    def cnt_np(t):
        return t.sum(-1) if task == "classification" else t[..., 0]

    acc = _NodeAccum(num_classes, task)
    root = acc.new_node(0)
    open_nodes = [root]                       # leaf id h (1-based) -> node id
    leaf_of = jnp.ones((n,), jnp.int32)       # all samples at the root
    stats_log: list[LevelStats] = []

    # the segment engine's leaf-ordered state; other engines read the
    # plain presorted layout (or the bucket state) and get zero-size
    # dummies for the layouts they don't use
    use_ord = plan.use_ord
    # root: all rows in leaf 1, so value order == (leaf, value) order
    ord_idx = sorted_idx if use_ord else jnp.zeros((0, 0), jnp.int32)

    tables = None                   # carried per-leaf hist tables (device)
    maps_src = None                 # (will_split, key_counts, L) of level-1
    no_tables = jnp.zeros((0, 0, 0, 0), jnp.float32)
    no_map = jnp.zeros((0,), jnp.int32)
    totals_np = None
    row_counts_np = None
    for depth in range(params.max_depth + 1):
        L = len(open_nodes)
        if L == 0:
            break
        Lp = _pad_leaves(L, params.leaf_pad)

        # leaf totals -> node values & forced closes (carried over from the
        # previous level's fused step; computed once at the root)
        if totals_np is None:
            totals_np = np.asarray(_leaf_totals(leaf_of, stats, w, Lp))
            row_counts_np = np.zeros(Lp + 1, np.int32)
            row_counts_np[1] = n
        else:
            cur = np.zeros((Lp + 1, totals_np.shape[1]), np.float32)
            cur[:L + 1] = totals_np[:L + 1]
            totals_np = cur
            cur_rc = np.zeros(Lp + 1, np.int32)
            k = min(L + 1, len(row_counts_np))   # only threaded if use_ord
            cur_rc[:k] = row_counts_np[:k]
            row_counts_np = cur_rc
        counts = cnt_np(totals_np)
        for h, node in enumerate(open_nodes, start=1):
            acc.set_value(node, totals_np[h], counts[h], task)

        at_max_depth = depth >= params.max_depth
        splittable = np.array(
            [counts[h] >= 2 * params.min_records and not at_max_depth
             for h in range(1, L + 1)] + [False] * (Lp - L))
        if not splittable.any():
            break
        splittable_p = np.concatenate([[False], splittable])

        # histogram subtraction: relate this frontier to the carried
        # previous-level tables (maps live on the host — tiny per-leaf
        # int arrays — and ride up with the other level inputs)
        subtract = bool(carries and tables is not None
                        and maps_src is not None)
        if subtract:
            parent_np, sib_np, slot_np = _child_maps(*maps_src, Lp)
            maps_dev = (tables, jnp.asarray(parent_np),
                        jnp.asarray(sib_np), jnp.asarray(slot_np))
        else:
            maps_dev = (no_tables, no_map, no_map, no_map)

        # the whole level on device: one dispatch, one small struct back
        _STEP_CALLS[0] += 1
        struct, leaf_of, ord_idx, next_totals, new_tables = \
            _fused_level_step(
                _zeros_unless(plan.pass_num or not hist, num, jnp.float32),
                cat, labels,
                _zeros_unless(plan.pass_sorted, sorted_vals, jnp.float32),
                _zeros_unless(plan.pass_sorted, sorted_idx, jnp.int32),
                bin_of,
                _zeros_unless(plan.pass_edges or not hist, bin_edges,
                              jnp.float32),
                ord_idx, leaf_of, w, stats,
                jnp.asarray(splittable_p), jnp.asarray(totals_np),
                jnp.asarray(row_counts_np), *maps_dev, fkey,
                jnp.int32(depth), plan=plan, Lp=Lp,
                need_partition=use_ord and depth + 1 < params.max_depth,
                subtract=subtract)
        if carries:
            tables = new_tables
        # non-blocking D2H of the small per-level struct
        for leaf in jax.tree_util.tree_leaves((struct, next_totals)):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        host, totals_np = jax.device_get((struct, next_totals))
        if use_ord or carries:
            row_counts_np = host["key_counts"]
        if carries:
            maps_src = (host["will_split"], host["key_counts"], L)

        # Alg. 2 step 8: the host bookkeeping — grow the flat tree
        next_open, any_split = _grow_level(acc, open_nodes, host, L, m_num,
                                           depth, edges_np=edges_np)

        if collect_stats:
            open_w = float(counts[1:L + 1].sum())
            tbl_w = (Lp // 2 + 1) if subtract else (Lp + 1)
            stats_log.append(LevelStats(
                depth=depth, open_leaves=L,
                network_bits_bitmap=int(open_w),
                network_bits_supersplit=int(m * (Lp + 1) * 64),
                class_list_bits=class_list.storage_bits(n, L),
                feature_passes=int(min(m_prime * (1 if params.usb else L), m)),
                rows_scanned=n * min(m_prime * (1 if params.usb else L), m),
                hist_table_bytes=(m_num * tbl_w * params.num_bins
                                  * int(stats.shape[-1]) * 4 if hist
                                  else 0)))

        if not any_split:
            break
        open_nodes = next_open

        # Sprint-style pruning switch (paper §3): compact rows in closed
        # leaves once they dominate (core/pruning.py).  Device-resident:
        # no host pass, no per-column numpy loop; under the leaf-ordered
        # layout the closed count is already on the host (row_counts[0]
        # from the level struct), so the trigger costs zero transfers.
        if params.prune_closed_frac < 1.0 and n > 0:
            # the ord layout is only current when this level partitioned it
            # (the last level before max_depth skips the partition; the loop
            # terminates right after, so skipping the prune there is free)
            order_current = not use_ord or (depth + 1 < params.max_depth)
            closed = (int(row_counts_np[0]) if use_ord or carries
                      else int(jnp.sum(leaf_of == 0)))
            drop = pruning.plan_drop(n, closed, plan.row_shards,
                                     params.prune_closed_frac)
            if drop and order_current:
                (n, leaf_of, ord_idx, sorted_vals, sorted_idx, bin_of, num,
                 cat, stats, w, labels) = pruning.compact_rows(
                    keep=pruning.keep_mask(leaf_of == 0, drop), drop=drop,
                    leaf_of=leaf_of, ord_idx=ord_idx,
                    sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                    bin_of=bin_of, num=num, cat=cat, stats=stats, w=w,
                    labels=labels, use_ord=use_ord, hist=hist, m_num=m_num)
                if use_ord or carries:
                    row_counts_np = row_counts_np.copy()
                    row_counts_np[0] -= drop   # dropped rows were leaf 0

    return _assemble_tree(acc, max_arity, m_num, task), stats_log


# ---------------------------------------------------------------------------
# The batched forest driver (vmap over tree state — DESIGN.md §3; the
# manager's parallel tree-builder queries answered by ONE device program)
# ---------------------------------------------------------------------------

def build_forest(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_indices,
    collect_stats: bool = False,
    bin_of: Optional[jnp.ndarray] = None,
    bin_edges: Optional[jnp.ndarray] = None,
    engine: Optional[SplitEngine] = None,
    cat_engine: Optional[SplitEngine] = None,
) -> tuple[list[Tree], list[list[LevelStats]]]:
    """Train a BATCH of trees with one fused jitted program per depth level.

    Trees are independent, so the whole fused level step is vmapped over a
    leading tree axis (DESIGN.md §3): per-tree PRNG keys, per-tree bootstrap
    row weights, and the per-tree leaf frontier padded to the batch maximum
    `Lp`, with trees that finish early masked via all-False `splittable`
    rows.  For T trees of depth D this issues D device programs total where
    the per-tree builder issues T·D — the dispatch/host-sync amortization
    that fills the machine at small-to-medium n.  Mesh engines
    (`level.sharded`) are batch-native: their shard_map'd search runs once
    per level on the stacked tree state, so SHARDED training keeps the same
    D-dispatch shape (see `level.plan._fused_level_step_batched`).

    The host loop is PIPELINED: after dispatching level d the driver first
    runs level d−1's deferred bookkeeping (`_grow_level`, node values) —
    overlapping it with the device executing level d — and only then blocks
    on level d's struct (whose D2H transfer was started eagerly with
    `copy_to_host_async`).  Bookkeeping order per tree is unchanged, so
    results are bit-identical to the unpipelined loop.

    Bit-parity: each returned tree is IDENTICAL to what
    `build_tree(..., tree_idx=t)` — and hence `build_tree_reference` —
    produces for the same (seed, t), for every backend and engine.
    Asserted by tests/test_forest_batch.py and tests/test_distributed.py.

    Args are as `build_tree`, except `tree_indices` (an iterable of tree
    ids, each seeding its own bagging/candidate streams) replaces
    `tree_idx`, and legacy `supersplit_fn` closures are not accepted
    (pass a `level.SplitEngine` via `engine=` instead).  Sprint pruning
    (`prune_closed_frac`) IS supported: rows closed in EVERY tree of the
    batch are dropped (a result-invariant subset of each tree's closed
    rows), keeping n divisible by any mesh engine's row-shard width.

    Returns (trees, stats_logs), parallel lists over `tree_indices`.
    """
    plan, (n, m_num, m_cat, m, max_arity, m_prime) = _make_plan(
        params, sorted_vals=sorted_vals, arities=arities, labels=labels,
        num_classes=num_classes, engine=engine, cat_engine=cat_engine)
    if isinstance(plan.numeric, LegacyFn):
        raise ValueError(
            "legacy supersplit_fn closures are per-tree only; pass a "
            "level.SplitEngine (engine=...) or use build_tree")
    task = params.task
    hist = params.split_mode == "hist"
    # device-put the (possibly host-lazy, see dataset.from_numpy) shared
    # inputs once, before the level loop
    num, cat, labels = jnp.asarray(num), jnp.asarray(cat), jnp.asarray(labels)
    sorted_vals = jnp.asarray(sorted_vals)
    sorted_idx = jnp.asarray(sorted_idx)
    # the bucket state is tree-independent (quantized once per forest):
    # shared read-only input of the batched step, like the presorted order
    bin_of, bin_edges = _hist_state(num, sorted_vals, params, m_num,
                                    bin_of, bin_edges)
    carries = plan.carries_tables       # hist subtraction (DESIGN.md §6)
    edges_np = np.asarray(bin_edges) if plan.use_bin_cuts else None
    tidx = [int(t) for t in tree_indices]
    T = len(tidx)
    assert T >= 1

    # per-tree stacked device state: bootstrap weights, stats, PRNG keys
    w = bagging.bag_counts_forest(seed, jnp.asarray(tidx, jnp.int32), n,
                                  params.bagging)                   # (T, n)
    stats = jax.vmap(
        lambda ww: splits.row_stats(labels, ww, num_classes, task))(w)
    S_dim = int(stats.shape[-1])
    base_key = jax.random.PRNGKey(seed ^ 0x5EED)
    fkeys = jax.vmap(lambda t: jax.random.fold_in(base_key, t))(
        jnp.asarray(tidx, jnp.int32))

    def cnt_np(t):
        return t.sum(-1) if task == "classification" else t[..., 0]

    accs = [_NodeAccum(num_classes, task) for _ in range(T)]
    open_nodes = [[a.new_node(0)] for a in accs]  # per tree: leaf h -> node
    leaf_of = jnp.ones((T, n), jnp.int32)
    stats_logs: list[list[LevelStats]] = [[] for _ in range(T)]

    use_ord = plan.use_ord
    # every tree starts at the root, where value order == (leaf, value)
    # order, so the initial per-tree leaf order is the shared presort
    ord_idx = (jnp.broadcast_to(sorted_idx[None], (T,) + sorted_idx.shape)
               if use_ord else jnp.zeros((T, 0, 0), jnp.int32))

    def write_values(Ls_d, counts_d, totals_d):
        """Node values of one level from its (host) leaf totals."""
        for t in range(T):
            for h in range(1, Ls_d[t] + 1):
                accs[t].set_value(open_nodes[t][h - 1], totals_d[t, h],
                                  counts_d[t, h], task)

    def make_book(depth_d, Ls_d, counts_d, totals_d, host_d, part_d, n_d):
        """Level d's deferred host bookkeeping (runs after dispatching
        level d+1; ordering per tree is exactly the unpipelined loop's)."""
        def book():
            write_values(Ls_d, counts_d, totals_d)
            for t in range(T):
                L = Ls_d[t]
                if L == 0 or not part_d[t]:
                    continue
                host_t = {k: host_d[k][t] for k in
                          ("best_feat", "best_gain", "thr", "mask",
                           "will_split")}
                next_open, any_split = _grow_level(
                    accs[t], open_nodes[t], host_t, L, m_num, depth_d,
                    edges_np=edges_np)
                if collect_stats:
                    # per-tree accounting under the tree's OWN padding, so
                    # the counters match a per-tree build of the same tree
                    Lp_t = _pad_leaves(L, params.leaf_pad)
                    open_w = float(counts_d[t, 1:L + 1].sum())
                    passes = int(min(m_prime * (1 if params.usb else L), m))
                    tbl_w = ((Lp_t // 2 + 1) if carries and depth_d > 0
                             else (Lp_t + 1))
                    stats_logs[t].append(LevelStats(
                        depth=depth_d, open_leaves=L,
                        network_bits_bitmap=int(open_w),
                        network_bits_supersplit=int(m * (Lp_t + 1) * 64),
                        class_list_bits=class_list.storage_bits(n_d, L),
                        feature_passes=passes, rows_scanned=n_d * passes,
                        hist_table_bytes=(m_num * tbl_w * params.num_bins
                                          * S_dim * 4 if hist else 0)))
                if any_split:
                    open_nodes[t] = next_open
        return book

    totals_np = None                      # (T, width, S), host
    row_counts_np = None                  # (T, width), host (ord layout)
    Ls = [1] * T                          # current frontier size per tree
    closed_np = 0                         # rows closed in EVERY tree
    pending = None                        # previous level's deferred book()
    tables = None                         # carried hist tables (device, T)
    maps_src = None                       # (ws, key_counts, Ls) of level-1
    no_tables = jnp.zeros((T, 0, 0, 0, 0), jnp.float32)
    no_map = jnp.zeros((T, 0), jnp.int32)
    for depth in range(params.max_depth + 1):
        if max(Ls) == 0:
            break
        Lp = _pad_leaves(max(Ls), params.leaf_pad)  # batch-max frontier

        # carry the leaf totals into the new padding (root: compute once)
        if totals_np is None:
            totals_np = np.asarray(jax.vmap(
                lambda lf, st, ww: _leaf_totals(lf, st, ww, Lp))(
                    leaf_of, stats, w))
            row_counts_np = np.zeros((T, Lp + 1), np.int32)
            row_counts_np[:, 1] = n
        else:
            cur = np.zeros((T, Lp + 1, totals_np.shape[-1]), np.float32)
            k = min(Lp + 1, totals_np.shape[1])   # rows past a tree's own
            cur[:, :k] = totals_np[:, :k]         # frontier are all zero
            totals_np = cur
            cur_rc = np.zeros((T, Lp + 1), np.int32)
            k = min(Lp + 1, row_counts_np.shape[1])
            cur_rc[:, :k] = row_counts_np[:, :k]
            row_counts_np = cur_rc
        counts = cnt_np(totals_np)                # (T, Lp+1)

        # the splittable frontier mask (per-tree node VALUES are written by
        # the deferred bookkeeping — they are not needed for dispatch)
        at_max_depth = depth >= params.max_depth
        splittable_p = np.zeros((T, Lp + 1), bool)
        participate = [False] * T
        if not at_max_depth:
            for t in range(T):
                if Ls[t] == 0:
                    continue
                sp = counts[t, 1:Ls[t] + 1] >= 2 * params.min_records
                if sp.any():
                    splittable_p[t, 1:Ls[t] + 1] = sp
                    participate[t] = True
        if not splittable_p.any():
            # nothing to dispatch: drain the pipeline, write the final
            # frontier's node values, stop
            if pending is not None:
                pending()
                pending = None
            write_values(Ls, counts, totals_np)
            Ls = [0] * T
            break

        # Sprint pruning (paper §3), batched: drop rows closed in EVERY
        # tree once they dominate (core/pruning.py).  Runs between levels
        # (before dispatch), so the ord layout is always current here — the
        # only level whose partition is skipped is the last one before
        # max_depth, and that iteration breaks above instead of reaching
        # this point.  The common-closed count rode home in the previous
        # level's struct (`closed_rows`), so the trigger costs no extra
        # dispatch or host sync and the pipelining stays intact.
        if params.prune_closed_frac < 1.0 and n > 0:
            drop = pruning.plan_drop(n, closed_np, plan.row_shards,
                                     params.prune_closed_frac)
            if drop:
                keep_open = (leaf_of > 0).any(axis=0)      # (n,) device
                (n, leaf_of, ord_idx, sorted_vals, sorted_idx, bin_of, num,
                 cat, stats, w, labels) = pruning.compact_rows(
                    keep=pruning.keep_mask(~keep_open, drop), drop=drop,
                    leaf_of=leaf_of, ord_idx=ord_idx,
                    sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                    bin_of=bin_of, num=num, cat=cat, stats=stats, w=w,
                    labels=labels, use_ord=use_ord, hist=hist, m_num=m_num)
                if use_ord or carries:
                    row_counts_np = row_counts_np.copy()
                    row_counts_np[:, 0] -= drop  # dropped rows were leaf 0
                closed_np -= drop

        # histogram subtraction: per-tree maps from the previous level's
        # split bitmap + child row counts (smaller child = build slot)
        subtract = bool(carries and tables is not None
                        and maps_src is not None)
        if subtract:
            ws_prev, kc_prev, Ls_prev = maps_src
            parent_b = np.zeros((T, Lp + 1), np.int32)
            sib_b = np.zeros((T, Lp + 1), np.int32)
            slot_b = np.zeros((T, Lp + 1), np.int32)
            for t in range(T):
                if Ls_prev[t]:
                    parent_b[t], sib_b[t], slot_b[t] = _child_maps(
                        ws_prev[t], kc_prev[t], Ls_prev[t], Lp)
            maps_dev = (tables, jnp.asarray(parent_b), jnp.asarray(sib_b),
                        jnp.asarray(slot_b))
        else:
            maps_dev = (no_tables, no_map, no_map, no_map)

        # the whole level of the whole batch on device: ONE dispatch,
        # one stacked struct back
        _BATCH_STEP_CALLS[0] += 1
        struct, leaf_of, ord_idx, next_totals, new_tables = \
            _fused_level_step_batched(
                _zeros_unless(plan.pass_num or not hist, num, jnp.float32),
                cat, labels,
                _zeros_unless(plan.pass_sorted, sorted_vals, jnp.float32),
                _zeros_unless(plan.pass_sorted, sorted_idx, jnp.int32),
                bin_of,
                _zeros_unless(plan.pass_edges or not hist, bin_edges,
                              jnp.float32),
                ord_idx, leaf_of, w, stats,
                jnp.asarray(splittable_p), jnp.asarray(totals_np),
                jnp.asarray(row_counts_np), *maps_dev, fkeys,
                jnp.int32(depth), plan=plan, Lp=Lp,
                need_partition=use_ord and depth + 1 < params.max_depth,
                subtract=subtract)
        if carries:
            tables = new_tables

        # pipeline: start the D2H transfer, run the PREVIOUS level's host
        # bookkeeping while the device executes this level, then block
        for leaf in jax.tree_util.tree_leaves((struct, next_totals)):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        if pending is not None:
            pending()
            pending = None

        totals_cur = totals_np            # this level's totals, for values
        host, totals_np = jax.device_get((struct, next_totals))
        if use_ord or carries:
            row_counts_np = host["key_counts"]
        if carries:
            maps_src = (host["will_split"], host["key_counts"], list(Ls))
        closed_np = int(host["closed_rows"])

        # next frontier sizes need only the split bitmap — the rest of the
        # bookkeeping is deferred to overlap the next dispatch
        ws = host["will_split"]
        Ls_next = [0] * T
        for t in range(T):
            if participate[t]:
                Ls_next[t] = 2 * int(ws[t, 1:Ls[t] + 1].sum())
        pending = make_book(depth, list(Ls), counts, totals_cur, host,
                            list(participate), n)
        Ls = Ls_next

    if pending is not None:               # safety drain (loop always breaks
        pending()                         # via the no-dispatch path above)

    return ([_assemble_tree(a, max_arity, m_num, task) for a in accs],
            stats_logs)


# ---------------------------------------------------------------------------
# The out-of-core streamed forest driver (DESIGN.md §8)
# ---------------------------------------------------------------------------

def build_forest_streamed(
    *,
    source,
    params: TreeParams, seed: int, tree_indices,
    collect_stats: bool = False,
    engine: Optional[SplitEngine] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    _checkpointer=None,
) -> tuple[list[Tree], list[list[LevelStats]]]:
    """Train a batch of hist-mode trees from a `dataset.RowSource`.

    The dataset never exists on device (nor, for `MemmapRowSource`, in
    host memory): per depth level the driver streams fixed-shape row
    chunks of the bit-packed bin cache through the jitted
    `_stream_chunk_step`, which replays the previous level's winning
    conditions on the chunk and folds it into the engine's per-leaf
    (feature, bin, stat) table accumulator.  One `_stream_finalize_step`
    merges the accumulator (the sharded engine's single per-level psum)
    and one `_stream_score_step` runs the exact `_level_step_core`
    candidate/score/winner arithmetic on the tables alone.  Leaf
    assignments live in a HOST (T, n) int32 array, written back chunk by
    chunk — peak device memory is bounded by the chunk size and the table
    width, independent of n.

    Restrictions (clear errors below): hist split mode only (exact needs
    the presort; only hist streams), classification only (integer-valued
    tables make chunked accumulation exact), numeric columns only, and
    the source's bucket budget must match `params.num_bins`.  Poisson /
    multinomial bagging draws the per-tree (n,) bootstrap weights on
    device once (the one n-sized transient, transferred to host
    immediately); `bagging="none"` streams with strictly chunk-bounded
    device memory.

    Bit-parity: produces node-for-node the trees of `build_forest` on the
    same quantized state for every chunk size, asserted by
    tests/test_stream_parity.py.

    Fault tolerance (DESIGN.md §9): with `checkpoint_dir=` the driver
    writes an atomic level snapshot of the host-side state every
    `checkpoint_every` completed levels (`repro.core.checkpoint`), and
    `resume=True` restarts from the last snapshot — or returns the
    finished trees immediately if this batch already completed —
    node-for-node bit-identical to an uninterrupted fit, because every
    remaining level replays the same pure chunk reads through the same
    programs.  Chunk reads are retried with exponential backoff on
    transient `OSError`s; a persistent failure flushes the held
    snapshot and raises `dataset.StreamReadError`.

    Returns (trees, stats_logs), parallel lists over `tree_indices`.
    """
    from repro.core import checkpoint as checkpoint_lib
    from repro.core import dataset as dataset_lib
    from repro.core.dataset import RowSource
    from repro.core.level.plan import (_STREAM_CHUNK_CALLS,
                                       _stream_chunk_step,
                                       _stream_finalize_step,
                                       _stream_score_step)
    if not isinstance(source, RowSource):
        raise TypeError(
            f"build_forest_streamed needs a dataset.RowSource, got "
            f"{type(source).__name__} — wrap the data with "
            f"ArrayRowSource.from_dataset / MemmapRowSource.build")
    if params.split_mode != "hist":
        raise ValueError(
            "streaming training requires split_mode='hist': exact mode "
            "needs the full presorted order, which cannot be built from a "
            "disk-backed source (exact needs the presort; only hist "
            "streams)")
    if params.task != "classification" or source.task != "classification":
        raise ValueError(
            "streaming training is classification-only: its chunked table "
            "accumulation is exact because classification tables hold "
            "integer-valued counts; regression y-sums could drift")
    if source.m_num < 1:
        raise ValueError("streaming training needs >= 1 numeric column")
    if source.num_bins != params.num_bins:
        raise ValueError(
            f"RowSource was quantized with num_bins={source.num_bins} but "
            f"TreeParams has num_bins={params.num_bins} — rebuild the "
            f"source or match the params")

    ck = _checkpointer
    if ck is None and checkpoint_dir is not None:
        ck = checkpoint_lib.StreamCheckpointer(checkpoint_dir,
                                               every=checkpoint_every)
        ck.prepare(source=source, params=params, seed=seed, resume=resume)
    if ck is not None and resume:
        done = ck.load_batch(tree_indices)
        if done is not None:        # batch committed by a previous run
            return done

    # subtraction is a no-op under fixed-shape chunks (every chunk is
    # scanned anyway), and PR 5 proved subtract == plain bit-identical,
    # so the streamed plan always runs the plain table build
    params_pl = dataclasses.replace(params, hist_subtract=False)
    m_num = source.m_num
    m_prime = params.num_candidates or max(
        1, math.isqrt(m_num) + (0 if math.isqrt(m_num) ** 2 == m_num else 1))
    plan = make_plan(params_pl, m_num=m_num, m_cat=0, max_arity=1,
                     num_classes=source.num_classes, m_prime=m_prime,
                     engine=engine)
    if not getattr(plan.numeric, "supports_stream", False):
        raise ValueError(
            f"engine {plan.numeric!r} does not support chunked "
            f"accumulation (supports_stream)")
    task = params.task
    num_classes = source.num_classes
    n = source.n
    statics = plan.statics
    edges_np = source.edges
    tidx = [int(t) for t in tree_indices]
    T = len(tidx)
    assert T >= 1

    # host-resident per-row state: labels, bootstrap weights, leaf ids
    labels_np = np.ascontiguousarray(source.labels, np.int32)
    if params.bagging == "none":
        w_np = np.ones((T, n), np.float32)
    else:
        # per-tree draws (bit-identical to bag_counts_forest), fetched to
        # host one at a time — the single n-sized device transient
        w_np = np.empty((T, n), np.float32)
        for i, t in enumerate(tidx):
            w_np[i] = np.asarray(bagging.bag_counts(seed, t, n,
                                                    params.bagging))
    base_key = jax.random.PRNGKey(seed ^ 0x5EED)
    fkeys = jax.vmap(lambda t: jax.random.fold_in(base_key, t))(
        jnp.asarray(tidx, jnp.int32))

    accs = [_NodeAccum(num_classes, task) for _ in range(T)]
    open_nodes = [[a.new_node(0)] for a in accs]
    stats_logs: list[list[LevelStats]] = [[] for _ in range(T)]
    leaf_np = np.ones((T, n), np.int32)
    active = None                   # original row ids of the active rows
    n_act = n
    Ls = [1] * T
    start_depth = 0

    rs = plan.row_shards
    chunk = max(1, int(source.chunk_size))
    # previous level's device-side decisions for the chunk reassignment
    dec = (jnp.zeros((T, 1), jnp.int32), jnp.zeros((T, 1), jnp.float32),
           jnp.zeros((T, 1), jnp.int32), jnp.zeros((T, 1), jnp.int32))
    Lpp = 0
    S_dim = num_classes

    if ck is not None and resume:
        snap = ck.load_snapshot(tidx)
        if snap is not None:
            # restore the end-of-level state and re-derive what was not
            # stored: labels come from the source, bag weights from the
            # seeded draws above — both exactly as a fresh fit computes
            # them — then the stored row map compacts them to n_act
            st = checkpoint_lib.unpack_stream_state(
                snap, num_classes=num_classes, task=task)
            start_depth = st["next_depth"]
            Ls, Lpp = st["Ls"], st["Lpp"]
            accs, open_nodes = st["accs"], st["open_nodes"]
            stats_logs = st["stats_logs"]
            leaf_np, active = st["leaf"], st["active"]
            n_act = leaf_np.shape[1]
            if active is not None:
                labels_np = np.ascontiguousarray(labels_np[active])
                w_np = np.ascontiguousarray(w_np[:, active])
            dec = tuple(jnp.asarray(d) for d in st["dec"])

    retry_kw = dict(attempts=source.retry_attempts,
                    base_delay=source.retry_base_delay,
                    max_delay=source.retry_max_delay,
                    sleep=source.retry_sleep)

    for depth in range(start_depth, params.max_depth + 1):
        if max(Ls) == 0:
            break
        Lp = _pad_leaves(max(Ls), params.leaf_pad)
        at_max_depth = depth >= params.max_depth
        need_tables = not at_max_depth
        root = depth == 0

        # --- chunk pass: reassign + accumulate --------------------------
        if need_tables:
            acc_dev = plan.numeric.stream_init(T, statics, Lp)
        else:       # terminal level: per-leaf stat totals only
            acc_dev = jnp.zeros((T, Lp + 1, S_dim), jnp.float32)
        # fixed-shape chunk buffers, padded to a row-shard multiple (pad
        # rows ride with w = 0 / leaf 0 and contribute exactly zero)
        C_buf = max(rs, -(-min(chunk, max(n_act, 1)) // rs) * rs)
        bins_buf = np.zeros((m_num, C_buf),
                            np.dtype(presort.bin_dtype(params.num_bins)))
        labels_buf = np.zeros((C_buf,), np.int32)
        w_buf = np.zeros((T, C_buf), np.float32)
        leaf_buf = np.zeros((T, C_buf), np.int32)
        for lo in range(0, n_act, C_buf):
            hi = min(lo + C_buf, n_act)
            c = hi - lo
            if c < C_buf:           # zero the pad of the final chunk
                bins_buf[:, c:] = 0
                labels_buf[c:] = 0
                w_buf[:, c:] = 0.0
                leaf_buf[:, c:] = 0
            try:
                bins_buf[:, :c] = dataset_lib.read_with_retry(
                    *((source.bins_block, lo, hi) if active is None
                      else (source.bins_take, active[lo:hi])), **retry_kw)
            except dataset_lib.StreamReadError:
                if ck is not None:  # persist the last completed level so
                    ck.flush()      # the resume loses only this one
                raise
            labels_buf[:c] = labels_np[lo:hi]
            w_buf[:, :c] = w_np[:, lo:hi]
            leaf_buf[:, :c] = leaf_np[:, lo:hi]
            _STREAM_CHUNK_CALLS[0] += 1
            leaf_c, acc_dev = _stream_chunk_step(
                bins_buf, labels_buf, w_buf, leaf_buf, *dec, acc_dev,
                plan=plan, Lp=Lp, Lpp=Lpp, root=root,
                need_tables=need_tables)
            leaf_np[:, lo:hi] = np.asarray(leaf_c)[:, :c]

        # --- finalize: merged tables + per-leaf totals -------------------
        if need_tables:
            merged, totals_dev = _stream_finalize_step(acc_dev, plan=plan)
            totals_np = np.asarray(totals_dev)
        else:
            merged, totals_np = None, np.asarray(acc_dev)
        counts = totals_np.sum(-1)                        # classification

        for t in range(T):
            for h in range(1, Ls[t] + 1):
                accs[t].set_value(open_nodes[t][h - 1], totals_np[t, h],
                                  counts[t, h], task)

        splittable_p = np.zeros((T, Lp + 1), bool)
        if not at_max_depth:
            for t in range(T):
                if Ls[t]:
                    splittable_p[t, 1:Ls[t] + 1] = \
                        counts[t, 1:Ls[t] + 1] >= 2 * params.min_records
        if not splittable_p.any():
            break                         # values already written

        # --- score: one program on the tables alone ----------------------
        res = _stream_score_step(merged, jnp.asarray(splittable_p), fkeys,
                                 jnp.int32(depth), plan=plan, Lp=Lp)
        host = jax.device_get({k: res[k] for k in
                               ("best_feat", "best_gain", "thr",
                                "will_split")})
        dec = (res["feat_of_leaf"], res["thr"], res["new_left"],
               res["new_right"])
        Lpp = Lp

        ws = host["will_split"]
        no_mask = np.zeros((Lp + 1, 1), bool)             # numeric-only
        Ls_next = [0] * T
        for t in range(T):
            if Ls[t] == 0:
                continue
            host_t = {k: host[k][t] for k in
                      ("best_feat", "best_gain", "thr", "will_split")}
            host_t["mask"] = no_mask
            next_open, any_split = _grow_level(
                accs[t], open_nodes[t], host_t, Ls[t], m_num, depth,
                edges_np=edges_np)
            if collect_stats:
                Lp_t = _pad_leaves(Ls[t], params.leaf_pad)
                passes = int(min(m_prime * (1 if params.usb else Ls[t]),
                                 m_num))
                stats_logs[t].append(LevelStats(
                    depth=depth, open_leaves=Ls[t],
                    network_bits_bitmap=int(counts[t, 1:Ls[t] + 1].sum()),
                    network_bits_supersplit=int(m_num * (Lp_t + 1) * 64),
                    class_list_bits=class_list.storage_bits(n_act, Ls[t]),
                    feature_passes=passes, rows_scanned=n_act * passes,
                    hist_table_bytes=m_num * (Lp_t + 1) * params.num_bins
                    * S_dim * 4))
            if any_split:
                open_nodes[t] = next_open
            Ls_next[t] = 2 * int(ws[t, 1:Ls[t] + 1].sum())
        Ls = Ls_next

        # --- Sprint pruning, HOST-side: drop rows closed in every tree ---
        # (result-invariant; fixed-shape padded chunks need no divisibility)
        if params.prune_closed_frac < 1.0 and n_act > 0 and max(Ls) > 0:
            open_any = (leaf_np > 0).any(axis=0)
            closed = n_act - int(open_any.sum())
            if closed > 0 and closed / n_act >= params.prune_closed_frac:
                keep = np.flatnonzero(open_any)
                active = keep if active is None else active[keep]
                leaf_np = np.ascontiguousarray(leaf_np[:, keep])
                w_np = np.ascontiguousarray(w_np[:, keep])
                labels_np = np.ascontiguousarray(labels_np[keep])
                n_act = len(keep)

        # end-of-level state, post-bookkeeping.  The final level's snapshot
        # is never written: finish_batch commits the trees immediately
        # after the loop, so its only possible consumer is a crash in that
        # gap — which the PREVIOUS snapshot already covers (one level of
        # recompute), and skipping it saves a write on every batch.
        if ck is not None and depth < params.max_depth:
            ck.save_snapshot(tidx, depth, checkpoint_lib.pack_stream_state(
                tidx=tidx, depth=depth, Ls=Ls, leaf_np=leaf_np,
                active=active, dec=dec, Lpp=Lpp, accs=accs,
                open_nodes=open_nodes, stats_logs=stats_logs))

    trees = [_assemble_tree(a, 1, m_num, task) for a in accs]
    if ck is not None:
        ck.finish_batch(tidx, trees, stats_logs)
    return trees, stats_logs


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_num", "iters"))
def _predict_jit(feature, threshold, is_cat, cat_mask, children, value,
                 num, cat, m_num, iters):
    B = num.shape[0] if num.size else cat.shape[0]
    node = jnp.zeros((B,), jnp.int32)

    def body(_, node):
        f = feature[node]
        leaf = f < 0
        jn = jnp.clip(f, 0, max(m_num - 1, 0))
        jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
        xnum = (jnp.take_along_axis(num, jn[:, None], 1)[:, 0]
                if num.size else jnp.zeros((B,), jnp.float32))
        xcat = (jnp.take_along_axis(cat, jc[:, None], 1)[:, 0]
                if cat.size else jnp.zeros((B,), jnp.int32))
        go_left = jnp.where(is_cat[node], cat_mask[node, xcat],
                            xnum <= threshold[node])
        nxt = jnp.where(go_left, children[node, 0], children[node, 1])
        return jnp.where(leaf, node, nxt)

    node = jax.lax.fori_loop(0, iters, body, node)
    return value[node]


def __getattr__(name):
    # `build_tree_reference` lives in repro.core.reference (which imports
    # this module); resolve it lazily to keep the historical
    # `tree.build_tree_reference` entry point without an import cycle.
    if name == "build_tree_reference":
        from repro.core.reference import build_tree_reference
        return build_tree_reference
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
