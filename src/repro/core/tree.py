"""Level-by-level decision tree builder (paper Alg. 2) + flat tree arrays.

The *tree builder* is the control plane (host Python, like the paper's tree
builder workers which "do not have access to the dataset"); the per-level
supersplit search and condition evaluation are the data plane (jitted JAX,
the paper's splitters).  All nodes of a depth are split together, so the
whole dataset is scanned once per candidate feature per LEVEL — never per
node — which is the paper's central complexity win over Sprint.

Data-plane structure (this is the hot path of the whole repo):

  * `build_tree` runs ONE fused jitted program per depth level
    (`_fused_level_step`): candidate draw, numeric supersplit (any
    backend), categorical supersplit, cross-feature winner argmax,
    condition evaluation (Alg. 2 step 5), leaf reassignment (step 6) and
    next-level leaf totals, all with device-resident `leaf_of`/`stats`/`w`
    state.  The host fetches exactly one small per-level struct (winning
    feature / threshold / mask / gain per open leaf) for node bookkeeping —
    the "one struct per level" protocol (DESIGN.md).
  * For the default `segment` backend the fused step also maintains a
    per-column (leaf, value)-sorted row order incrementally: children are
    stable partitions of the parent's contiguous block, an O(n) segmented
    cumsum per level instead of the per-level O(n log n) counting sort.
  * `build_forest` trains a whole BATCH of trees per level program — the
    same fused step vmapped (or lax.map'd) over a leading tree axis, T·D →
    D dispatches per forest, bit-identical per tree (DESIGN.md §3).
  * `build_tree_reference` is the pre-fusion builder (one jitted call per
    piece, numpy round-trips between them).  It is kept as the executable
    specification: parity tests assert the fused builder reproduces its
    trees exactly, and benchmarks/level_step_bench.py measures the speedup.

Per-level network/disk accounting (paper Table 1) is recorded in
`LevelStats` by the builder: one bit per sample per level broadcast
("Dn bits in D allreduce"), the ⌈log2(ℓ+1)⌉·n class-list bits, and the
number of sequential passes over the data.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, class_list, presort, splits


# ---------------------------------------------------------------------------
# Hyper-parameters & flat tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeParams:
    max_depth: int = 20
    min_records: float = 1.0        # paper: "minimum number of records in a leaf"
    num_candidates: Optional[int] = None  # m' (None = ceil(sqrt(m)), the paper default)
    impurity: str = "gini"          # gini | entropy | variance
    task: str = "classification"
    backend: str = "segment"        # segment | scan | kernel (Pallas)
    # exact = the paper's midpoint-exhaustive search (default); hist = the
    # PLANET-style contrast baseline: numeric columns quantized once into
    # <= num_bins buckets, splits scored on bucket boundaries only, from
    # per-leaf (bin × class) count tables (DESIGN.md §6)
    split_mode: str = "exact"       # exact | hist
    num_bins: int = 255             # histogram-mode bucket budget per column
    usb: bool = False               # unique set of bagged features per depth (§3.2)
    bagging: str = "poisson"        # poisson | multinomial | none
    leaf_pad: int = 8               # pad open-leaf count to multiples (recompile bound)
    # Sprint-style record pruning (paper §3): when the fraction of samples
    # sitting in CLOSED leaves reaches this threshold, compact the dataset
    # (drop those rows, filter the presorted order — no re-sort needed).
    # 1.0 disables it, which is the paper's Leo configuration ("this
    # operation is not triggered during the experimentation").
    prune_closed_frac: float = 1.0


@dataclasses.dataclass
class Tree:
    """Flat-array decision tree (numpy, host-side)."""
    feature: np.ndarray        # (N,) int32; -1 = leaf
    threshold: np.ndarray      # (N,) float32 (numeric nodes)
    is_cat: np.ndarray         # (N,) bool
    cat_mask: np.ndarray       # (N, max_arity) bool; True -> go LEFT
    children: np.ndarray       # (N, 2) int32 [left, right]
    value: np.ndarray          # (N, C) class distribution / (N, 1) mean
    n_node: np.ndarray         # (N,) in-bag weight reaching the node
    gain: np.ndarray           # (N,) split gain (0 for leaves)
    depth: np.ndarray          # (N,) int32
    m_num: int
    task: str

    @property
    def num_nodes(self) -> int:
        return len(self.feature)

    @property
    def num_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth_reached(self) -> int:
        return int(self.depth.max()) if self.num_nodes else 0

    def node_density(self) -> float:
        """Paper §5: #leaves / 2^D for the deepest depth D."""
        d = self.max_depth_reached
        return self.num_leaves / float(2 ** d) if d else 1.0

    def sample_density(self) -> float:
        """Paper §5: fraction of in-bag weight reaching depth-D leaves."""
        d = self.max_depth_reached
        leaves = self.feature < 0
        bottom = leaves & (self.depth == d)
        tot = self.n_node[leaves].sum()
        return float(self.n_node[bottom].sum() / tot) if tot > 0 else 0.0

    def predict_raw(self, num: jnp.ndarray, cat: jnp.ndarray) -> jnp.ndarray:
        """(B, C) distributions / (B, 1) means."""
        return _predict_jit(
            jnp.asarray(self.feature), jnp.asarray(self.threshold),
            jnp.asarray(self.is_cat), jnp.asarray(self.cat_mask),
            jnp.asarray(self.children), jnp.asarray(self.value),
            num, cat, self.m_num, int(self.depth.max()) + 1)


@dataclasses.dataclass
class LevelStats:
    """Per-level complexity counters (benchmarks/table1)."""
    depth: int
    open_leaves: int
    network_bits_bitmap: int     # the 1-bit-per-sample broadcast
    network_bits_supersplit: int # partial supersplit payloads (tiny)
    class_list_bits: int         # n * ceil(log2(l+1))
    feature_passes: int          # sequential passes over candidate columns
    rows_scanned: int


# ---------------------------------------------------------------------------
# Jitted per-level pieces
# ---------------------------------------------------------------------------

def _pad_leaves(L: int, pad: int) -> int:
    """Pad to a power of two (recompilation count is O(log leaves))."""
    return max(pad, 1 << (L - 1).bit_length())


@jax.jit
def _gather_sorted_level(sorted_idx, leaf_of, w, stats):
    """Per-column gathers of the level state in presorted order."""
    return leaf_of[sorted_idx], w[sorted_idx], stats[sorted_idx]


def _numeric_supersplits(backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                         cand, Lp, impurity, task, min_records):
    """vmap the chosen exact backend over numerical columns.

    sorted_vals/sorted_idx: (m_num, n); cand: (m_num, Lp+1).
    Returns gains (m_num, Lp+1), thresholds (m_num, Lp+1).
    """
    fn = splits.NUMERIC_BACKENDS[backend]
    def per_col(v, si, cl):
        lf, ww, st = _gather_sorted_level(si, leaf_of, w, stats)
        return fn(v, lf, ww, st, cl, Lp, impurity, task, min_records)
    return jax.vmap(per_col)(sorted_vals, sorted_idx, cand)


def _categorical_supersplits(cat_cols, leaf_of, w, stats, cand, Lp, max_arity,
                             impurity, task, min_records):
    """vmap exact categorical search over columns padded to max_arity."""
    def per_col(x, cl):
        return splits.best_categorical_split(
            x, leaf_of, w, stats, cl, Lp, max_arity, impurity, task, min_records)
    return jax.vmap(per_col)(cat_cols, cand)


def _eval_conditions_core(num, cat, leaf_of, feat_of_leaf, thr_of_leaf,
                          iscat_of_leaf, mask_of_leaf, m_num):
    """Alg. 2 step 5: evaluate the winning condition of each sample's leaf.

    Returns bits (n,) bool — True = LEFT.  In the distributed engine this is
    the 1-bit-per-sample payload that gets allreduced (see distributed.py).
    """
    f = feat_of_leaf[leaf_of]                                   # (n,)
    jn = jnp.clip(f, 0, max(m_num - 1, 0))
    jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
    xnum = jnp.take_along_axis(num, jn[:, None], axis=1)[:, 0] if num.size else jnp.zeros_like(leaf_of, jnp.float32)
    xcat = jnp.take_along_axis(cat, jc[:, None], axis=1)[:, 0] if cat.size else jnp.zeros_like(leaf_of)
    num_bit = xnum <= thr_of_leaf[leaf_of]
    cat_bit = mask_of_leaf[leaf_of, xcat]
    return jnp.where(iscat_of_leaf[leaf_of], cat_bit, num_bit)


_evaluate_conditions = functools.partial(jax.jit, static_argnames=("m_num",))(
    _eval_conditions_core)


@functools.partial(jax.jit, static_argnames=("Lp",))
def _leaf_totals(leaf_of, stats, w, Lp):
    inbag = (w > 0) & (leaf_of > 0)
    return jax.ops.segment_sum(jnp.where(inbag[:, None], stats, 0.0),
                               leaf_of, num_segments=Lp + 1)


@jax.jit
def _reassign(leaf_of, bits, new_left, new_right):
    """Alg. 2 step 6: map samples to child leaf ids (0 if child closed)."""
    child = jnp.where(bits, new_left[leaf_of], new_right[leaf_of])
    return jnp.where(leaf_of > 0, child, 0)


# ---------------------------------------------------------------------------
# The fused level step (one jitted device program per depth)
# ---------------------------------------------------------------------------

def _partition_leaf_order(ord_idx, lf_pos, bits, new_left, new_right,
                          row_counts, key_counts):
    """Advance the per-column (leaf, value)-sorted order to the next level.

    Children occupy consecutive id ranges in parent order (left id <
    right id, parents in id order, closed = 0), so the stable counting sort
    by the NEW leaf id reduces to: closed rows to the front (stable), then
    a stable left/right partition inside each parent's contiguous block —
    O(n) work with ONE cumsum and ONE scatter per column, no sort.
    Relative row order inside every child equals the parent's
    (value-ascending), exactly what a stable sort would produce, so the
    `segment` backend's summation order — and hence its float results —
    are preserved bit-for-bit.

    The block structure is column-independent (same leaf histogram in every
    column), so everything except the row permutation itself — `lf_pos`,
    the current `row_counts` (L+1,) and next-level `key_counts` (2L+1,)
    histograms, block starts, target offsets — is computed once.  Only the
    1-bit condition outcome `bits` (row-indexed) is gathered per column.

    Accepts an optional LEADING TREE AXIS on every argument
    (ord_idx (T, m, n), the rest (T, ...)): the batched level step calls it
    this way, outside its tree-axis vmap, so the permutation lands in ONE
    flat scatter over all T·m columns — XLA lowers a batched-operand
    scatter (what vmap would produce) far slower than the same scatter on a
    flattened index space (~2x on CPU, measured).  The per-tree call takes
    the same flat-scatter path with T = 1.
    """
    batched = ord_idx.ndim == 3
    if not batched:
        ord_idx, lf_pos, bits = ord_idx[None], lf_pos[None], bits[None]
        new_left, new_right = new_left[None], new_right[None]
        row_counts, key_counts = row_counts[None], key_counts[None]
    B, m, n = ord_idx.shape

    def shared(lf_pos, new_left, new_right, row_counts, key_counts):
        # parents either split wholly or close wholly, so a block is
        # all-closed or all-left/right; closed rows keep their block order,
        # preceded by the closed rows of earlier parents
        parent_closed = new_left == 0                         # (Lp+1,)
        closed_sizes = jnp.where(parent_closed, row_counts, 0)
        closed_before = jnp.cumsum(closed_sizes) - closed_sizes
        offs = jnp.cumsum(key_counts) - key_counts            # per new key
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), lf_pos[1:] != lf_pos[:-1]])
        start_idx = jax.lax.cummax(jnp.where(is_start, jnp.arange(n), -1))
        in_block = jnp.arange(n) - start_idx                  # rank in block
        return (start_idx, in_block, parent_closed[lf_pos],
                closed_before[lf_pos] + in_block,             # (n,) shared
                offs[new_left[lf_pos]], offs[new_right[lf_pos]])

    start_idx, in_block, closed_here, pos_closed, offs_l, offs_r = \
        jax.vmap(shared)(lf_pos, new_left, new_right, row_counts, key_counts)

    wl = jax.vmap(lambda b, oi: b[oi])(                       # went LEFT
        bits, ord_idx.reshape(B, m * n)).reshape(B, m, n)
    cl = jnp.cumsum(wl.astype(jnp.int32), axis=2) - wl
    si = jnp.broadcast_to(start_idx[:, None, :], (B, m, n))
    left_rank = cl - jnp.take_along_axis(cl, si, axis=2)
    pos = jnp.where(
        closed_here[:, None, :], pos_closed[:, None, :],
        jnp.where(wl, offs_l[:, None, :] + left_rank,
                  offs_r[:, None, :] + in_block[:, None, :] - left_rank))
    if B * m * n < 2 ** 31:
        base = (jnp.arange(B * m, dtype=jnp.int32) * n).reshape(B, m, 1)
        out = jnp.zeros((B * m * n,), ord_idx.dtype).at[
            (pos + base).reshape(-1)].set(ord_idx.reshape(-1),
                                          unique_indices=True
                                          ).reshape(B, m, n)
    else:
        # the flat index space would overflow int32 (x64 is off); fall back
        # to per-column scatters, whose indices stay < n
        out = jax.vmap(jax.vmap(
            lambda p, o: jnp.zeros_like(o).at[p].set(
                o, unique_indices=True)))(pos, ord_idx)
    return out if batched else out[0]


_LEVEL_STATICS = (
    "Lp", "m_num", "m_cat", "max_arity", "num_classes", "m_prime", "usb",
    "impurity", "task", "min_records", "backend", "split_mode", "num_bins",
    "use_ord", "need_partition", "supersplit_fn")

# Dispatch/trace counters: tests assert the batched builder issues ONE
# jitted level program per depth per tree-batch (and never falls back to
# per-tree dispatches).  CALLS bump at dispatch time, TRACES at trace time.
_STEP_CALLS = [0]          # per-tree fused level dispatches (build_tree)
_BATCH_STEP_CALLS = [0]    # batched level dispatches (build_forest)
_BATCH_STEP_TRACES = [0]   # distinct compilations of the batched program

# Above this many row-state elements (T·m_num·n) the batched level step
# switches from vmap (SIMD across trees) to lax.map (sequential trees, one
# program) — the vmapped stack stops being cache-resident and measures
# ~1.5x slower on CPU; see `_fused_level_step_batched`.
_BATCH_VMAP_ELEMS = 1 << 19


def _level_step_core(num, cat, labels, sorted_vals, sorted_idx, bin_of,
                     bin_edges, ord_idx, leaf_of, w, stats, splittable_p,
                     totals, row_counts, fkey, depth, *, Lp, m_num, m_cat,
                     max_arity, num_classes, m_prime, usb, impurity, task,
                     min_records, backend, split_mode, num_bins, use_ord,
                     need_partition, supersplit_fn, fused_tail=True):
    """One whole depth level of Alg. 2 as a single device program.

    Steps 3-7 fused: candidate feature draw, numeric + categorical
    supersplit search, partial-supersplit merge (cross-feature argmax),
    condition evaluation, leaf reassignment, and the next level's leaf
    totals.  Only the returned per-leaf struct (winning feature, gain,
    threshold, category mask, split bitmap) is fetched by the host; the
    row-indexed state (`leaf_of`, the per-column leaf order) stays
    device-resident.

    `split_mode` (static) selects the numeric search: "exact" runs the
    paper's midpoint-exhaustive engines over the presorted order; "hist"
    (the PLANET-style baseline, DESIGN.md §6) scores only the `num_bins`
    bucket boundaries from per-leaf (bin × stat) count tables built by the
    categorical scatter-add machinery (`bin_of`/`bin_edges` replace
    `sorted_vals`/`sorted_idx` — no presorted state in the hot path).

    `supersplit_fn` (static) replaces the local numeric search with the
    shard_map'd distributed one — it composes under this jit, so the same
    fused program runs on the mesh (distributed.py).  In hist mode its
    signature takes (bin_of, bin_edges, ...) instead of the sorted order
    (distributed.make_hist_sharded_supersplit).
    """
    L1 = Lp + 1
    m = m_num + m_cat
    n = leaf_of.shape[0]

    # Alg. 2 step 3: seeded per-leaf candidate features (paper §2.2/§2.4)
    cand = bagging.candidate_features(fkey, depth, Lp, m, m_prime, usb)
    cand = cand & splittable_p[1:, None]
    cand_p = jnp.concatenate([jnp.zeros((1, m), bool), cand], 0)  # leaf 0

    gains_parts, masks = [], None
    thr_num = jnp.zeros((max(m_num, 1), L1), jnp.float32)
    if m_num and split_mode == "hist":
        cnum = cand_p[:, :m_num].T
        if supersplit_fn is not None:
            g, t = supersplit_fn(bin_of, bin_edges, leaf_of, w, stats,
                                 cnum, Lp, impurity, task, min_records)
        else:
            if backend == "kernel":
                from repro.kernels import ops as kops
                tables = kops.categorical_tables(
                    bin_of, leaf_of, w, labels, V=num_bins, Lp=Lp, task=task,
                    num_classes=num_classes)
            else:
                tables = jax.vmap(
                    lambda b: splits.categorical_count_table(
                        b, leaf_of, w, stats, Lp, num_bins))(bin_of)
            g, t = jax.vmap(
                lambda tb, e, c: splits.best_numeric_split_histogram(
                    tb, e, c, impurity, task, min_records))(
                tables, bin_edges, cnum)
        gains_parts.append(g)
        thr_num = t
    elif m_num:
        cnum = cand_p[:, :m_num].T
        if supersplit_fn is not None:
            g, t = supersplit_fn(sorted_vals, sorted_idx, leaf_of, w, stats,
                                 cnum, Lp, impurity, task, min_records)
        elif backend == "kernel":
            from repro.kernels import ops as kops
            g, t = kops.split_scan_supersplit(
                sorted_vals, sorted_idx, leaf_of, w, labels, cnum, Lp,
                impurity, task, min_records, num_classes=num_classes)
        elif use_ord:
            # leaf-ordered fast path: no per-level counting sort.  Shared
            # per-leaf totals are exact for classification (integer bag
            # counts); regression reduces per column to keep the reference
            # builder's float summation order bit-for-bit.
            tot = totals if task == "classification" else None
            lf_pos = leaf_of[ord_idx[0]]            # same for every column
            inbag = (w > 0)[ord_idx] & (lf_pos > 0)[None]
            ord_vals = jnp.take_along_axis(num.T, ord_idx, axis=1)
            g, t = splits.best_numeric_split_leaf_ordered(
                ord_vals, lf_pos, inbag, stats[ord_idx],
                cnum, Lp, impurity, task, min_records, totals=tot,
                row_counts=row_counts)
        else:
            g, t = _numeric_supersplits(
                backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                cnum, Lp, impurity, task, min_records)
        gains_parts.append(g)
        thr_num = t
    if m_cat:
        ccat = cand_p[:, m_num:].T
        if backend == "kernel":
            from repro.kernels import ops as kops
            tables = kops.categorical_tables(
                cat.T, leaf_of, w, labels, V=max_arity, Lp=Lp, task=task,
                num_classes=num_classes)
            g, masks = jax.vmap(
                lambda tb, c: splits.best_categorical_split_from_table(
                    tb, c, impurity, task, min_records))(tables, ccat)
        else:
            g, masks = _categorical_supersplits(
                cat.T, leaf_of, w, stats, ccat, Lp, max_arity, impurity,
                task, min_records)
        gains_parts.append(g)

    all_gains = jnp.concatenate(gains_parts, axis=0)            # (m, L1)

    # tree builder merges partial supersplits (Alg. 2 step 3, final argmax)
    best_feat = jnp.argmax(all_gains, axis=0).astype(jnp.int32)  # (L1,)
    best_gain = jnp.take_along_axis(all_gains, best_feat[None], 0)[0]
    will_split = splittable_p & jnp.isfinite(best_gain) & (best_gain > 1e-9)

    # children get consecutive 1-based ids in leaf order (Alg. 2 step 6)
    ks = jnp.cumsum(will_split.astype(jnp.int32))
    new_left = jnp.where(will_split, 2 * ks - 1, 0).astype(jnp.int32)
    new_right = jnp.where(will_split, 2 * ks, 0).astype(jnp.int32)

    feat_of_leaf = jnp.where(will_split, best_feat, 0).astype(jnp.int32)
    iscat_of_leaf = will_split & (best_feat >= m_num) if m_cat else \
        jnp.zeros((L1,), bool)
    thr_sel = jnp.take_along_axis(
        thr_num, jnp.clip(best_feat, 0, max(m_num - 1, 0))[None], 0)[0]
    thr_of_leaf = jnp.where(will_split & ~iscat_of_leaf, thr_sel, 0.0)
    if m_cat:
        jc = jnp.clip(best_feat - m_num, 0, m_cat - 1)
        mask_sel = masks[jc, jnp.arange(L1)]                    # (L1, V)
        mask_of_leaf = jnp.where(iscat_of_leaf[:, None], mask_sel, False)
    else:
        mask_of_leaf = jnp.zeros((L1, max_arity), bool)

    # Alg. 2 steps 5-6: 1-bit condition per sample, reassign to children
    bits = _eval_conditions_core(num, cat, leaf_of, feat_of_leaf,
                                 thr_of_leaf, iscat_of_leaf, mask_of_leaf,
                                 m_num)
    new_leaf_of = jnp.where(
        leaf_of > 0,
        jnp.where(bits, new_left[leaf_of], new_right[leaf_of]), 0)

    struct = {"best_feat": best_feat, "best_gain": best_gain,
              "thr": thr_of_leaf, "mask": mask_of_leaf,
              "will_split": will_split}
    if not fused_tail:
        # batched mode: the scatter-backed reductions (next totals, key
        # counts, order partition) run OUTSIDE the tree-axis vmap, on a
        # flattened (tree, segment) index space — vmap would lower them as
        # batched-operand scatters, ~2x slower on CPU.  Hand back the
        # per-tree pieces the wrapper needs.
        part = (bits, new_left, new_right) if use_ord else None
        return struct, new_leaf_of, ord_idx, None, part

    # next-level totals (node values / counts / splittable for depth+1)
    inb = (w > 0) & (new_leaf_of > 0)
    next_totals = jax.ops.segment_sum(jnp.where(inb[:, None], stats, 0.0),
                                      new_leaf_of, num_segments=2 * Lp + 1)

    if use_ord:
        key_counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32),
                                         new_leaf_of, num_segments=2 * Lp + 1)
        # becomes the next level's row_counts (host slices to the new Lp)
        struct["key_counts"] = key_counts
        if need_partition:
            new_ord_idx = _partition_leaf_order(
                ord_idx, lf_pos, bits, new_left, new_right, row_counts,
                key_counts)
        else:       # the next level cannot split again (max depth reached)
            new_ord_idx = ord_idx
    else:
        new_ord_idx = ord_idx
    return struct, new_leaf_of, new_ord_idx, next_totals, None


@functools.partial(jax.jit, static_argnames=_LEVEL_STATICS)
def _fused_level_step(num, cat, labels, sorted_vals, sorted_idx, bin_of,
                      bin_edges, ord_idx, leaf_of, w, stats, splittable_p,
                      totals, row_counts, fkey, depth, *, Lp, m_num, m_cat,
                      max_arity, num_classes, m_prime, usb, impurity, task,
                      min_records, backend, split_mode, num_bins, use_ord,
                      need_partition, supersplit_fn):
    """The per-tree fused level step (see `_level_step_core`)."""
    struct, new_leaf_of, new_ord_idx, next_totals, _ = _level_step_core(
        num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
        ord_idx, leaf_of, w, stats, splittable_p, totals, row_counts, fkey,
        depth, Lp=Lp, m_num=m_num, m_cat=m_cat, max_arity=max_arity,
        num_classes=num_classes, m_prime=m_prime, usb=usb, impurity=impurity,
        task=task, min_records=min_records, backend=backend,
        split_mode=split_mode, num_bins=num_bins, use_ord=use_ord,
        need_partition=need_partition, supersplit_fn=supersplit_fn)
    return struct, new_leaf_of, new_ord_idx, next_totals


@functools.partial(jax.jit, static_argnames=_LEVEL_STATICS)
def _fused_level_step_batched(num, cat, labels, sorted_vals, sorted_idx,
                              bin_of, bin_edges, ord_idx, leaf_of, w, stats,
                              splittable_p, totals, row_counts, fkeys, depth,
                              *, Lp, m_num, m_cat, max_arity, num_classes,
                              m_prime, usb, impurity, task, min_records,
                              backend, split_mode, num_bins, use_ord,
                              need_partition, supersplit_fn):
    """One depth level of EVERY tree in a batch as a single device program.

    Trees are independent, so the whole fused level step — candidate draw,
    numeric + categorical supersplit, winner argmax, condition evaluation,
    leaf reassignment, next-level totals, incremental leaf-order partition —
    is `vmap`ped over a leading tree axis T.  Shared read-only inputs (the
    raw columns, labels, the forest-wide presorted order) broadcast; the
    per-tree state batches:

        num (n, m_num), cat (n, m_cat), labels (n,),
        sorted_vals/sorted_idx (m_num, n)              [shared, in_axes=None]
        ord_idx (T, m_num, n), leaf_of (T, n), w (T, n), stats (T, n, S),
        splittable_p (T, Lp+1), totals (T, Lp+1, S), row_counts (T, Lp+1),
        fkeys (T, key)                                 [batched, in_axes=0]

    `Lp` is the batch-wide padded frontier width (max over the batch's
    trees); trees with fewer open leaves — or none, having finished early —
    are masked through `splittable_p`, which zeroes their candidate sets so
    every gain is −inf and `will_split` stays False.  Because
    `bagging.candidate_features` is padding-independent (per-leaf fold-in),
    batching under the shared `Lp` is bit-identical per tree to the
    per-tree `_fused_level_step` under that tree's own padding — the
    property tests/test_forest_batch.py asserts against the reference
    builder.  The Pallas paths (`split_scan`, `cat_hist`) batch through
    `pallas_call`'s vmap rule, which folds the tree axis into the kernel
    grid — still one device program.

    Two lowering strategies, chosen statically by batch working-set size:

      * SIMD across trees (`vmap` of the core, scatters flattened over the
        (tree, segment) index space) when the batch's row state is
        cache-resident — the fast path at small n, where dispatch overhead
        dominates and cross-tree vectorization is free;
      * sequential trees (`lax.map` of the per-tree core) when the stacked
        state would thrash cache (measured ~1.5x slower under vmap on CPU
        at T=16, n=100k) — still ONE device program per level, so the
        T·D → D dispatch/host-sync amortization is kept at every size.

    Returns the per-tree struct dict and next-level state, all with the
    leading T axis; the host fetches the structs in ONE transfer per level.
    """
    _BATCH_STEP_TRACES[0] += 1
    T, n = leaf_of.shape
    if T * max(m_num, 1) * n > _BATCH_VMAP_ELEMS:
        # cache-bound regime: run the trees sequentially INSIDE the program
        core = functools.partial(
            _level_step_core, Lp=Lp, m_num=m_num, m_cat=m_cat,
            max_arity=max_arity, num_classes=num_classes, m_prime=m_prime,
            usb=usb, impurity=impurity, task=task, min_records=min_records,
            backend=backend, split_mode=split_mode, num_bins=num_bins,
            use_ord=use_ord, need_partition=need_partition,
            supersplit_fn=supersplit_fn, fused_tail=True)

        def body(args):
            ord_t, leaf_t, w_t, stats_t, sp_t, tot_t, rc_t, fk_t = args
            s, nl, no, nt, _ = core(num, cat, labels, sorted_vals,
                                    sorted_idx, bin_of, bin_edges, ord_t,
                                    leaf_t, w_t, stats_t, sp_t, tot_t, rc_t,
                                    fk_t, depth)
            return s, nl, no, nt

        return jax.lax.map(body, (ord_idx, leaf_of, w, stats, splittable_p,
                                  totals, row_counts, fkeys))

    core = functools.partial(
        _level_step_core, Lp=Lp, m_num=m_num, m_cat=m_cat,
        max_arity=max_arity, num_classes=num_classes, m_prime=m_prime,
        usb=usb, impurity=impurity, task=task, min_records=min_records,
        backend=backend, split_mode=split_mode, num_bins=num_bins,
        use_ord=use_ord, need_partition=need_partition,
        supersplit_fn=supersplit_fn, fused_tail=False)
    struct, new_leaf_of, _, _, part = jax.vmap(
        core, in_axes=(None, None, None, None, None, None, None,
                       0, 0, 0, 0, 0, 0, 0, 0, None))(
        num, cat, labels, sorted_vals, sorted_idx, bin_of, bin_edges,
        ord_idx, leaf_of, w, stats, splittable_p, totals, row_counts, fkeys,
        depth)

    # scatter-backed tail on the FLAT (tree, segment) index space: per-tree
    # results are bit-identical (each tree's rows accumulate in the same
    # order as in the per-tree program) but the scatters lower ~2x faster
    # than their vmapped form on CPU
    L2 = 2 * Lp + 1
    flat_ids = (new_leaf_of
                + jnp.arange(T, dtype=jnp.int32)[:, None] * L2).reshape(-1)
    inb = (w > 0) & (new_leaf_of > 0)
    next_totals = jax.ops.segment_sum(
        jnp.where(inb.reshape(-1)[:, None], stats.reshape(T * n, -1), 0.0),
        flat_ids, num_segments=T * L2).reshape(T, L2, -1)
    if use_ord:
        key_counts = jax.ops.segment_sum(
            jnp.ones((T * n,), jnp.int32), flat_ids,
            num_segments=T * L2).reshape(T, L2)
        struct = dict(struct, key_counts=key_counts)
        if need_partition:
            bits, new_left, new_right = part
            lf_pos = jax.vmap(lambda lf, oi: lf[oi])(leaf_of, ord_idx[:, 0])
            new_ord_idx = _partition_leaf_order(
                ord_idx, lf_pos, bits, new_left, new_right, row_counts,
                key_counts)
        else:
            new_ord_idx = ord_idx
    else:
        new_ord_idx = ord_idx
    return struct, new_leaf_of, new_ord_idx, next_totals


# ---------------------------------------------------------------------------
# The tree builder (Alg. 2)
# ---------------------------------------------------------------------------

def _tree_setup(sorted_vals, arities, labels, params):
    if params.split_mode not in ("exact", "hist"):
        raise ValueError(f"unknown split_mode {params.split_mode!r} "
                         "(expected 'exact' or 'hist')")
    if params.split_mode == "hist" and params.num_bins < 2:
        raise ValueError("hist mode needs num_bins >= 2")
    n = int(labels.shape[0])
    m_num = int(sorted_vals.shape[0]) if sorted_vals.size else 0
    m_cat = len(arities)
    m = m_num + m_cat
    max_arity = max(arities) if arities else 1
    m_prime = params.num_candidates or max(
        1, math.isqrt(m) + (0 if math.isqrt(m) ** 2 == m else 1))
    return n, m_num, m_cat, m, max_arity, m_prime


def _hist_state(num, sorted_vals, params, m_num, bin_of, bin_edges):
    """Resolve the hist-mode bucket state (zero-size dummies in exact mode).

    When the caller (RandomForest/GBTModel.fit) did not precompute the
    quantization, derive it here from the presorted values — once per tree
    build, shared by every level.
    """
    if params.split_mode == "hist" and m_num:
        if bin_of is None:
            bin_of, bin_edges = presort.quantize(num, sorted_vals,
                                                 params.num_bins)
        return bin_of, bin_edges
    return jnp.zeros((0, 0), jnp.int32), jnp.zeros((0, 0), jnp.float32)


class _NodeAccum:
    """Host-side flat-tree accumulator (Alg. 2 step 8 bookkeeping).

    One per tree; the builders append nodes level by level and
    `_assemble_tree` freezes the lists into the numpy `Tree` arrays.
    """

    def __init__(self, num_classes: int, task: str):
        self.feature: list = []
        self.threshold: list = []
        self.is_cat: list = []
        self.cat_mask: list = []
        self.children: list = []
        self.value: list = []
        self.n_node: list = []
        self.gain: list = []
        self.depth: list = []
        self._C = max(num_classes, 2) if task == "classification" else 1

    def new_node(self, depth: int) -> int:
        self.feature.append(-1)
        self.threshold.append(0.0)
        self.is_cat.append(False)
        self.cat_mask.append(None)
        self.children.append([-1, -1])
        self.value.append(np.zeros(self._C, np.float32))
        self.n_node.append(0.0)
        self.gain.append(0.0)
        self.depth.append(depth)
        return len(self.feature) - 1

    def set_value(self, node: int, totals_row: np.ndarray, count: float,
                  task: str) -> None:
        """Node value from its leaf-totals row (distribution / mean)."""
        self.n_node[node] = float(count)
        if task == "classification":
            tot = max(count, 1e-12)
            self.value[node] = (totals_row / tot).astype(np.float32)
        else:
            wsum = max(totals_row[0], 1e-12)
            self.value[node] = np.array([totals_row[1] / wsum], np.float32)


def _grow_level(acc: _NodeAccum, open_nodes: list, host: dict, L: int,
                m_num: int, depth: int) -> tuple[list, bool]:
    """Alg. 2 step 8 for ONE tree: grow the flat tree from a level struct.

    `host` holds the fetched per-leaf arrays of one tree (best_feat /
    best_gain / thr / mask / will_split, each (Lp+1,)-indexed by leaf id).
    Shared by `build_tree` and `build_forest` so their bookkeeping cannot
    drift.  Returns (next level's open node ids, whether any leaf split).
    """
    bf, bg = host["best_feat"], host["best_gain"]
    thr, mask, ws = host["thr"], host["mask"], host["will_split"]
    next_open: list[int] = []
    any_split = False
    for h in range(1, L + 1):
        if not ws[h]:
            continue
        node = open_nodes[h - 1]
        j = int(bf[h])
        any_split = True
        acc.feature[node] = j
        acc.gain[node] = float(bg[h])
        if j < m_num:
            acc.threshold[node] = float(thr[h])
        else:
            acc.is_cat[node] = True
            acc.cat_mask[node] = mask[h].copy()
        lc, rc = acc.new_node(depth + 1), acc.new_node(depth + 1)
        acc.children[node] = [lc, rc]
        next_open.extend([lc, rc])
    return next_open, any_split


def build_tree(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_idx: int,
    collect_stats: bool = False,
    supersplit_fn=None,
    bin_of: Optional[jnp.ndarray] = None,
    bin_edges: Optional[jnp.ndarray] = None,
) -> tuple[Tree, list[LevelStats]]:
    """Train ONE tree with one fused jitted device program per depth level.

    Args (shapes):
      num / cat:     (n, m_num) float32 / (n, m_cat) int32 raw columns.
      labels:        (n,) int32 class ids (classification) or float32
                     targets (regression).
      sorted_vals / sorted_idx: (m_num, n) per-column presorted values and
                     row indices (presort.presort_columns) — computed once
                     per forest and shared by every tree.
      arities:       per categorical column arity; categories are
                     0..arity-1, padded to max(arities) inside the step.
      num_classes:   stat width C for classification (S = C); regression
                     uses S = 3 ([w, wy, wy²]) regardless.
      params:        TreeParams; `params.backend` picks the numeric
                     supersplit engine — "segment" (default; incrementally
                     maintained (leaf, value)-sorted layout, no per-level
                     sort), "scan" (faithful Alg. 1 sequential pass) or
                     "kernel" (Pallas split_scan/cat_hist; interpret mode
                     off-TPU).
      seed/tree_idx: seeded bagging + candidate draws (paper §2.2) — all
                     randomness is a pure function of these two.
      supersplit_fn: optional replacement for the local numeric supersplit
                     (distributed.py passes the shard_map'd search; it
                     composes inside the fused jit so the same program
                     lowers for the mesh).  Under `split_mode="hist"` the
                     expected signature is the histogram one
                     (make_hist_sharded_supersplit).
      bin_of/bin_edges: hist-mode bucket state ((m_num, n) int32 bucket ids
                     and (m_num, num_bins) f32 upper edges) as produced by
                     `TabularDataset.quantize`; derived here from
                     `sorted_vals` when omitted.  Ignored in exact mode.

    Produces exactly the trees of `build_tree_reference` (asserted by
    tests/test_fused_level.py) while the host does bookkeeping only: per
    level it uploads the tiny (splittable, totals) pair and fetches one
    small per-leaf struct; all row-indexed state stays on device.  To train
    many trees, prefer `build_forest`, which runs this same level step
    vmapped over a whole tree batch.

    Returns (Tree, [LevelStats]) — the flat host-side tree and, when
    `collect_stats`, the per-level paper-Table-1 counters.
    """
    n, m_num, m_cat, m, max_arity, m_prime = _tree_setup(
        sorted_vals, arities, labels, params)
    task = params.task
    hist = params.split_mode == "hist"
    bin_of, bin_edges = _hist_state(num, sorted_vals, params, m_num,
                                    bin_of, bin_edges)

    w = bagging.bag_counts(seed, tree_idx, n, params.bagging)
    stats = splits.row_stats(labels, w, num_classes, task)
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)

    def cnt_np(t):
        return t.sum(-1) if task == "classification" else t[..., 0]

    acc = _NodeAccum(num_classes, task)
    root = acc.new_node(0)
    open_nodes = [root]                       # leaf id h (1-based) -> node id
    leaf_of = jnp.ones((n,), jnp.int32)       # all samples at the root
    stats_log: list[LevelStats] = []

    # the segment backend's leaf-ordered state; other backends read the
    # plain presorted layout and get zero-size dummies for the other one
    # (hist mode reads neither: bucket tables are scatter-adds in row order)
    use_ord = (params.backend == "segment" and supersplit_fn is None
               and m_num > 0 and not hist)
    # root: all rows in leaf 1, so value order == (leaf, value) order
    ord_idx = sorted_idx if use_ord else jnp.zeros((0, 0), jnp.int32)

    totals_np = None
    row_counts_np = None
    for depth in range(params.max_depth + 1):
        L = len(open_nodes)
        if L == 0:
            break
        Lp = _pad_leaves(L, params.leaf_pad)

        # leaf totals -> node values & forced closes (carried over from the
        # previous level's fused step; computed once at the root)
        if totals_np is None:
            totals_np = np.asarray(_leaf_totals(leaf_of, stats, w, Lp))
            row_counts_np = np.zeros(Lp + 1, np.int32)
            row_counts_np[1] = n
        else:
            cur = np.zeros((Lp + 1, totals_np.shape[1]), np.float32)
            cur[:L + 1] = totals_np[:L + 1]
            totals_np = cur
            cur_rc = np.zeros(Lp + 1, np.int32)
            k = min(L + 1, len(row_counts_np))   # only threaded if use_ord
            cur_rc[:k] = row_counts_np[:k]
            row_counts_np = cur_rc
        counts = cnt_np(totals_np)
        for h, node in enumerate(open_nodes, start=1):
            acc.set_value(node, totals_np[h], counts[h], task)

        at_max_depth = depth >= params.max_depth
        splittable = np.array(
            [counts[h] >= 2 * params.min_records and not at_max_depth
             for h in range(1, L + 1)] + [False] * (Lp - L))
        if not splittable.any():
            break
        splittable_p = np.concatenate([[False], splittable])

        # the whole level on device: one dispatch, one small struct back
        _STEP_CALLS[0] += 1
        skip_sorted = use_ord or hist      # neither layout reads the presort
        struct, leaf_of, ord_idx, next_totals = _fused_level_step(
            num, cat, labels,
            jnp.zeros((0, 0), jnp.float32) if skip_sorted else sorted_vals,
            jnp.zeros((0, 0), jnp.int32) if skip_sorted else sorted_idx,
            bin_of, bin_edges, ord_idx, leaf_of, w, stats,
            jnp.asarray(splittable_p), jnp.asarray(totals_np),
            jnp.asarray(row_counts_np), fkey,
            jnp.int32(depth), Lp=Lp, m_num=m_num, m_cat=m_cat,
            max_arity=max_arity, num_classes=num_classes, m_prime=m_prime,
            usb=params.usb, impurity=params.impurity, task=task,
            min_records=params.min_records, backend=params.backend,
            split_mode=params.split_mode, num_bins=params.num_bins,
            use_ord=use_ord,
            need_partition=use_ord and depth + 1 < params.max_depth,
            supersplit_fn=supersplit_fn)
        host, totals_np = jax.device_get((struct, next_totals))
        if use_ord:
            row_counts_np = host["key_counts"]

        # Alg. 2 step 8: the host bookkeeping — grow the flat tree
        next_open, any_split = _grow_level(acc, open_nodes, host, L, m_num,
                                           depth)

        if collect_stats:
            open_w = float(counts[1:L + 1].sum())
            stats_log.append(LevelStats(
                depth=depth, open_leaves=L,
                network_bits_bitmap=int(open_w),
                network_bits_supersplit=int(m * (Lp + 1) * 64),
                class_list_bits=class_list.storage_bits(n, L),
                feature_passes=int(min(m_prime * (1 if params.usb else L), m)),
                rows_scanned=n * min(m_prime * (1 if params.usb else L), m)))

        if not any_split:
            break
        open_nodes = next_open

        # Sprint-style pruning switch (paper §3): compact rows in closed
        # leaves once they dominate.  Device-resident: under the
        # leaf-ordered layout the closed rows are the CONTIGUOUS PREFIX of
        # every column's order (new leaf id 0 sorts first), so compaction is
        # a per-column slice + index remap — no host pass, no per-column
        # numpy loop.  The closed count itself is already on the host
        # (row_counts[0] from the level struct), so the trigger costs zero
        # extra transfers.
        if params.prune_closed_frac < 1.0 and n > 0:
            # the ord layout is only current when this level partitioned it
            # (the last level before max_depth skips the partition; the loop
            # terminates right after, so skipping the prune there is free)
            order_current = not use_ord or (depth + 1 < params.max_depth)
            closed = (int(row_counts_np[0]) if use_ord
                      else int(jnp.sum(leaf_of == 0)))
            if closed / n >= params.prune_closed_frac and 0 < closed < n \
                    and order_current:
                n_new = n - closed
                keep = leaf_of > 0
                remap = jnp.cumsum(keep.astype(jnp.int32)) - 1
                keep_idx = jnp.nonzero(keep, size=n_new)[0]
                if use_ord:
                    # closed rows = positions [0, closed) in EVERY column
                    ord_idx = jnp.take(remap, ord_idx[:, closed:])
                    row_counts_np = row_counts_np.copy()
                    row_counts_np[0] = 0      # the dropped (closed) rows
                elif hist:
                    # bucket ids are row-indexed; no sorted state to filter
                    if m_num:
                        bin_of = bin_of[:, keep_idx]
                elif m_num:
                    # filter the presorted order (stability preserves it):
                    # every column keeps the same n_new rows, so the flat
                    # row-major nonzero is (m_num, n_new) column blocks
                    kept_cols = jnp.take(keep, sorted_idx)
                    flat = jnp.nonzero(kept_cols.reshape(-1),
                                       size=m_num * n_new)[0]
                    sorted_idx = jnp.take(
                        remap, sorted_idx.reshape(-1)[flat]
                    ).reshape(m_num, n_new)
                    sorted_vals = sorted_vals.reshape(-1)[flat].reshape(
                        m_num, n_new)
                num = num[keep_idx]
                cat = cat[keep_idx]
                stats = stats[keep_idx]
                w = w[keep_idx]
                labels = labels[keep_idx]
                leaf_of = leaf_of[keep_idx]
                n = n_new

    return _assemble_tree(acc, max_arity, m_num, task), stats_log


# ---------------------------------------------------------------------------
# The batched forest builder (vmap over tree state — ROADMAP
# "multi-tree level batching": the manager's parallel tree-builder queries
# answered by ONE device, DESIGN.md §3)
# ---------------------------------------------------------------------------

def build_forest(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_indices,
    collect_stats: bool = False,
    bin_of: Optional[jnp.ndarray] = None,
    bin_edges: Optional[jnp.ndarray] = None,
) -> tuple[list[Tree], list[list[LevelStats]]]:
    """Train a BATCH of trees with one fused jitted program per depth level.

    Trees are independent, so the whole fused level step is vmapped over a
    leading tree axis (DESIGN.md §3): per-tree PRNG keys, per-tree bootstrap
    row weights, and the per-tree leaf frontier padded to the batch maximum
    `Lp`, with trees that finish early masked via all-False `splittable`
    rows.  For T trees of depth D this issues D device programs total where
    the per-tree builder issues T·D — the dispatch/host-sync amortization
    that fills the machine at small-to-medium n.

    Bit-parity: each returned tree is IDENTICAL to what
    `build_tree(..., tree_idx=t)` — and hence `build_tree_reference` —
    produces for the same (seed, t), for every backend.  Two properties
    carry this: `bagging.candidate_features` draws per leaf row (so the
    batch-max padding does not perturb a tree's own draws), and the vmapped
    level step performs the same per-tree reductions in the same order as
    the unbatched one.  Asserted by tests/test_forest_batch.py.

    Args are as `build_tree`, except `tree_indices` (an iterable of tree
    ids, each seeding its own bagging/candidate streams) replaces
    `tree_idx`, and `supersplit_fn`/`prune_closed_frac` are not supported —
    `RandomForest.fit` routes those configurations to the per-tree builder.

    Returns (trees, stats_logs), parallel lists over `tree_indices`.
    """
    n, m_num, m_cat, m, max_arity, m_prime = _tree_setup(
        sorted_vals, arities, labels, params)
    task = params.task
    hist = params.split_mode == "hist"
    # the bucket state is tree-independent (quantized once per forest):
    # shared read-only input of the batched step, like the presorted order
    bin_of, bin_edges = _hist_state(num, sorted_vals, params, m_num,
                                    bin_of, bin_edges)
    tidx = [int(t) for t in tree_indices]
    T = len(tidx)
    assert T >= 1
    assert params.prune_closed_frac >= 1.0, \
        "row pruning changes n per tree; use the per-tree builder"

    # per-tree stacked device state: bootstrap weights, stats, PRNG keys
    w = bagging.bag_counts_forest(seed, jnp.asarray(tidx, jnp.int32), n,
                                  params.bagging)                   # (T, n)
    stats = jax.vmap(
        lambda ww: splits.row_stats(labels, ww, num_classes, task))(w)
    base_key = jax.random.PRNGKey(seed ^ 0x5EED)
    fkeys = jax.vmap(lambda t: jax.random.fold_in(base_key, t))(
        jnp.asarray(tidx, jnp.int32))

    def cnt_np(t):
        return t.sum(-1) if task == "classification" else t[..., 0]

    accs = [_NodeAccum(num_classes, task) for _ in range(T)]
    open_nodes = [[a.new_node(0)] for a in accs]  # per tree: leaf h -> node
    done = [False] * T                    # finished trees stay masked
    leaf_of = jnp.ones((T, n), jnp.int32)
    stats_logs: list[list[LevelStats]] = [[] for _ in range(T)]

    use_ord = params.backend == "segment" and m_num > 0 and not hist
    # every tree starts at the root, where value order == (leaf, value)
    # order, so the initial per-tree leaf order is the shared presort
    ord_idx = (jnp.broadcast_to(sorted_idx[None], (T,) + sorted_idx.shape)
               if use_ord else jnp.zeros((T, 0, 0), jnp.int32))

    totals_np = None                      # (T, width, S), host
    row_counts_np = None                  # (T, width), host (ord backend)
    for depth in range(params.max_depth + 1):
        Ls = [0 if done[t] else len(open_nodes[t]) for t in range(T)]
        if max(Ls) == 0:
            break
        Lp = _pad_leaves(max(Ls), params.leaf_pad)  # batch-max frontier

        # carry the leaf totals into the new padding (root: compute once)
        if totals_np is None:
            totals_np = np.asarray(jax.vmap(
                lambda lf, st, ww: _leaf_totals(lf, st, ww, Lp))(
                    leaf_of, stats, w))
            row_counts_np = np.zeros((T, Lp + 1), np.int32)
            row_counts_np[:, 1] = n
        else:
            cur = np.zeros((T, Lp + 1, totals_np.shape[-1]), np.float32)
            k = min(Lp + 1, totals_np.shape[1])   # rows past a tree's own
            cur[:, :k] = totals_np[:, :k]         # frontier are all zero
            totals_np = cur
            cur_rc = np.zeros((T, Lp + 1), np.int32)
            k = min(Lp + 1, row_counts_np.shape[1])
            cur_rc[:, :k] = row_counts_np[:, :k]
            row_counts_np = cur_rc
        counts = cnt_np(totals_np)                # (T, Lp+1)

        # per-tree node values + the splittable frontier mask
        at_max_depth = depth >= params.max_depth
        splittable_p = np.zeros((T, Lp + 1), bool)
        for t in range(T):
            if done[t]:
                continue
            for h, node in enumerate(open_nodes[t], start=1):
                accs[t].set_value(node, totals_np[t, h], counts[t, h], task)
            if at_max_depth:
                done[t] = True                    # values written; no splits
                continue
            sp = counts[t, 1:Ls[t] + 1] >= 2 * params.min_records
            if not sp.any():
                done[t] = True
                continue
            splittable_p[t, 1:Ls[t] + 1] = sp
        if not splittable_p.any():
            break

        # the whole level of the whole batch on device: ONE dispatch,
        # one stacked struct back
        _BATCH_STEP_CALLS[0] += 1
        skip_sorted = use_ord or hist
        struct, leaf_of, ord_idx, next_totals = _fused_level_step_batched(
            num, cat, labels,
            jnp.zeros((0, 0), jnp.float32) if skip_sorted else sorted_vals,
            jnp.zeros((0, 0), jnp.int32) if skip_sorted else sorted_idx,
            bin_of, bin_edges, ord_idx, leaf_of, w, stats,
            jnp.asarray(splittable_p), jnp.asarray(totals_np),
            jnp.asarray(row_counts_np), fkeys,
            jnp.int32(depth), Lp=Lp, m_num=m_num, m_cat=m_cat,
            max_arity=max_arity, num_classes=num_classes, m_prime=m_prime,
            usb=params.usb, impurity=params.impurity, task=task,
            min_records=params.min_records, backend=params.backend,
            split_mode=params.split_mode, num_bins=params.num_bins,
            use_ord=use_ord,
            need_partition=use_ord and depth + 1 < params.max_depth,
            supersplit_fn=None)
        host, totals_np = jax.device_get((struct, next_totals))
        if use_ord:
            row_counts_np = host["key_counts"]

        # Alg. 2 step 8 per tree: grow the flat trees from the structs
        for t in range(T):
            if done[t]:
                continue
            L = Ls[t]
            host_t = {k: host[k][t] for k in ("best_feat", "best_gain",
                                              "thr", "mask", "will_split")}
            next_open, any_split = _grow_level(accs[t], open_nodes[t],
                                               host_t, L, m_num, depth)

            if collect_stats:
                # per-tree accounting under the tree's OWN padding, so the
                # counters match a per-tree build of the same tree
                Lp_t = _pad_leaves(L, params.leaf_pad)
                open_w = float(counts[t, 1:L + 1].sum())
                passes = int(min(m_prime * (1 if params.usb else L), m))
                stats_logs[t].append(LevelStats(
                    depth=depth, open_leaves=L,
                    network_bits_bitmap=int(open_w),
                    network_bits_supersplit=int(m * (Lp_t + 1) * 64),
                    class_list_bits=class_list.storage_bits(n, L),
                    feature_passes=passes, rows_scanned=n * passes))

            if any_split:
                open_nodes[t] = next_open
            else:
                done[t] = True

    return ([_assemble_tree(a, max_arity, m_num, task) for a in accs],
            stats_logs)


# ---------------------------------------------------------------------------
# The reference (pre-fusion) tree builder — executable specification
# ---------------------------------------------------------------------------

def build_tree_reference(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params: TreeParams, seed: int, tree_idx: int,
    collect_stats: bool = False,
    supersplit_fn=None,
) -> tuple[Tree, list[LevelStats]]:
    """The seed builder: one jitted call per level piece, numpy in between.

    Kept as the executable specification of Alg. 2 — the fused `build_tree`
    must reproduce its trees exactly (tests/test_fused_level.py), and
    benchmarks/level_step_bench.py measures the fused speedup against it.
    EXACT mode only: the histogram mode is an approximation with no
    midpoint-exhaustive specification to match (its tests compare the
    batched builder against the per-tree fused builder instead).
    """
    assert params.split_mode == "exact", \
        "build_tree_reference is the exact-mode specification"
    n, m_num, m_cat, m, max_arity, m_prime = _tree_setup(
        sorted_vals, arities, labels, params)
    task = params.task

    w = bagging.bag_counts(seed, tree_idx, n, params.bagging)
    stats = splits.row_stats(labels, w, num_classes, task)
    cnt = splits.count_fn(task)
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)

    acc = _NodeAccum(num_classes, task)
    root = acc.new_node(0)
    open_nodes = [root]                       # leaf id h (1-based) -> node id
    leaf_of = jnp.ones((n,), jnp.int32)       # all samples at the root
    stats_log: list[LevelStats] = []

    for depth in range(params.max_depth + 1):
        L = len(open_nodes)
        if L == 0:
            break
        Lp = _pad_leaves(L, params.leaf_pad)

        # leaf totals -> node values & forced closes
        totals = np.asarray(_leaf_totals(leaf_of, stats, w, Lp))  # (Lp+1, S)
        counts = np.asarray(cnt(jnp.asarray(totals)))
        for h, node in enumerate(open_nodes, start=1):
            acc.set_value(node, totals[h], counts[h], task)

        at_max_depth = depth >= params.max_depth
        splittable = np.array(
            [counts[h] >= 2 * params.min_records and not at_max_depth
             for h in range(1, L + 1)] + [False] * (Lp - L))
        if not splittable.any():
            break

        # Alg. 2 step 3: query the splitters for the optimal supersplit
        cand = bagging.candidate_features(fkey, depth, Lp, m, m_prime, params.usb)
        cand = cand & jnp.asarray(splittable)[:, None]
        cand_p = jnp.concatenate([jnp.zeros((1, m), bool), cand], 0)  # leaf 0 = closed

        all_gains = np.full((m, Lp + 1), -np.inf, np.float32)
        all_thr = np.zeros((m, Lp + 1), np.float32)
        all_masks = None
        if m_num:
            if supersplit_fn is not None:
                g, t = supersplit_fn(
                    sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            elif params.backend == "kernel":
                from repro.kernels import ops as kops
                g, t = kops.split_scan_supersplit(
                    sorted_vals, sorted_idx, leaf_of, w, labels,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records, num_classes=num_classes)
            else:
                g, t = _numeric_supersplits(
                    params.backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            all_gains[:m_num], all_thr[:m_num] = np.asarray(g), np.asarray(t)
        if m_cat:
            g, masks = _categorical_supersplits(
                cat.T, leaf_of, w, stats, cand_p[:, m_num:].T, Lp, max_arity,
                params.impurity, task, params.min_records)
            all_gains[m_num:] = np.asarray(g)
            all_masks = np.asarray(masks)                    # (m_cat, Lp+1, V)

        # tree builder merges partial supersplits (Alg. 2 step 3, final argmax)
        best_feat = all_gains.argmax(axis=0)                 # (Lp+1,)
        best_gain = all_gains[best_feat, np.arange(Lp + 1)]

        # Alg. 2 step 8: close leaves with no good condition
        feat_of_leaf = np.zeros(Lp + 1, np.int32)
        thr_of_leaf = np.zeros(Lp + 1, np.float32)
        iscat_of_leaf = np.zeros(Lp + 1, bool)
        mask_of_leaf = np.zeros((Lp + 1, max_arity), bool)
        new_left = np.zeros(Lp + 1, np.int32)
        new_right = np.zeros(Lp + 1, np.int32)
        next_open: list[int] = []
        any_split = False
        for h in range(1, L + 1):
            node = open_nodes[h - 1]
            if not splittable[h - 1] or not np.isfinite(best_gain[h]) or best_gain[h] <= 1e-9:
                continue
            j = int(best_feat[h])
            any_split = True
            acc.feature[node] = j
            acc.gain[node] = float(best_gain[h])
            feat_of_leaf[h] = j
            if j < m_num:
                acc.threshold[node] = float(all_thr[j, h])
                thr_of_leaf[h] = all_thr[j, h]
            else:
                acc.is_cat[node] = True
                iscat_of_leaf[h] = True
                cm = all_masks[j - m_num, h]
                acc.cat_mask[node] = cm.copy()
                mask_of_leaf[h] = cm
            lc, rc = acc.new_node(depth + 1), acc.new_node(depth + 1)
            acc.children[node] = [lc, rc]
            next_open.extend([lc, rc])
            new_left[h] = len(next_open) - 1               # 1-based ids below
            new_right[h] = len(next_open)

        if collect_stats:
            open_w = float(counts[1:L + 1].sum())
            stats_log.append(LevelStats(
                depth=depth, open_leaves=L,
                network_bits_bitmap=int(open_w),
                network_bits_supersplit=int(m * (Lp + 1) * 64),
                class_list_bits=class_list.storage_bits(n, L),
                feature_passes=int(min(m_prime * (1 if params.usb else L), m)),
                rows_scanned=n * min(m_prime * (1 if params.usb else L), m)))

        if not any_split:
            break

        # Alg. 2 steps 5-7: evaluate conditions (1 bit/sample) and reassign
        bits = _evaluate_conditions(
            num, cat, leaf_of, jnp.asarray(feat_of_leaf), jnp.asarray(thr_of_leaf),
            jnp.asarray(iscat_of_leaf), jnp.asarray(mask_of_leaf), m_num)
        leaf_of = _reassign(leaf_of, bits, jnp.asarray(new_left), jnp.asarray(new_right))
        open_nodes = next_open

        # Sprint-style pruning switch (paper §3): compact rows in closed
        # leaves once they dominate.  The presorted order is FILTERED, not
        # re-sorted (stability preserves it), so the one-time cost is one
        # pass — the trade-off rule the paper describes.
        if params.prune_closed_frac < 1.0 and n > 0:
            lf_np = np.asarray(leaf_of)
            keep = lf_np > 0
            frac_closed = 1.0 - keep.mean()
            if frac_closed >= params.prune_closed_frac and keep.any() \
                    and keep.sum() < n:
                remap = np.cumsum(keep) - 1
                idx_np = np.asarray(sorted_idx)
                vals_np = np.asarray(sorted_vals)
                kept_cols = keep[idx_np]                      # (m_num, n)
                n_new = int(keep.sum())
                new_idx = np.empty((m_num, n_new), np.int32)
                new_vals = np.empty((m_num, n_new), np.float32)
                for j in range(m_num):
                    sel = kept_cols[j]
                    new_idx[j] = remap[idx_np[j][sel]]
                    new_vals[j] = vals_np[j][sel]
                sorted_idx = jnp.asarray(new_idx)
                sorted_vals = jnp.asarray(new_vals)
                num = num[jnp.asarray(keep)] if num.size else num
                cat = cat[jnp.asarray(keep)] if cat.size else cat
                stats = stats[jnp.asarray(keep)]
                w = w[jnp.asarray(keep)]
                labels = labels[jnp.asarray(keep)]
                leaf_of = jnp.asarray(lf_np[keep])
                n = n_new

    return _assemble_tree(acc, max_arity, m_num, task), stats_log


def _assemble_tree(acc: _NodeAccum, max_arity, m_num, task) -> Tree:
    N = len(acc.feature)
    cat_mask_arr = np.zeros((N, max_arity), bool)
    for i, cm in enumerate(acc.cat_mask):
        if cm is not None:
            cat_mask_arr[i, :len(cm)] = cm
    return Tree(
        feature=np.asarray(acc.feature, np.int32),
        threshold=np.asarray(acc.threshold, np.float32),
        is_cat=np.asarray(acc.is_cat, bool),
        cat_mask=cat_mask_arr,
        children=np.asarray(acc.children, np.int32),
        value=np.stack(acc.value).astype(np.float32),
        n_node=np.asarray(acc.n_node, np.float32),
        gain=np.asarray(acc.gain, np.float32),
        depth=np.asarray(acc.depth, np.int32),
        m_num=m_num, task=task)


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m_num", "iters"))
def _predict_jit(feature, threshold, is_cat, cat_mask, children, value,
                 num, cat, m_num, iters):
    B = num.shape[0] if num.size else cat.shape[0]
    node = jnp.zeros((B,), jnp.int32)

    def body(_, node):
        f = feature[node]
        leaf = f < 0
        jn = jnp.clip(f, 0, max(m_num - 1, 0))
        jc = jnp.clip(f - m_num, 0, max(cat.shape[1] - 1, 0))
        xnum = (jnp.take_along_axis(num, jn[:, None], 1)[:, 0]
                if num.size else jnp.zeros((B,), jnp.float32))
        xcat = (jnp.take_along_axis(cat, jc[:, None], 1)[:, 0]
                if cat.size else jnp.zeros((B,), jnp.int32))
        go_left = jnp.where(is_cat[node], cat_mask[node, xcat],
                            xnum <= threshold[node])
        nxt = jnp.where(go_left, children[node, 0], children[node, 1])
        return jnp.where(leaf, node, nxt)

    node = jax.lax.fori_loop(0, iters, body, node)
    return value[node]
