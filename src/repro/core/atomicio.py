"""Crash-safe file writes: tmp + `os.replace` (DESIGN.md §9).

Every durable artifact this repo writes — `PackedForest.save` models,
`MemmapRowSource` cache metadata, streamed-training checkpoints — goes
through `atomic_replace`: the bytes land in a same-directory temp file
first and `os.replace` (atomic on POSIX within one filesystem) installs
them under the final name.  A kill at ANY instruction therefore leaves
either the complete old file or the complete new file, never a
truncated hybrid.

The module-level hooks exist for the fault-injection harness
(`repro.testing.faults`): tests arm them to SIGKILL the process in the
worst possible window (after the tmp write, before the replace) and
then prove the artifact on disk is still the intact previous version.
"""
from __future__ import annotations

import json
import os
from typing import Callable

# Test hooks (repro.testing.faults). `PRE_REPLACE_HOOK(final_path,
# tmp_path)` runs after the tmp file is fully written, immediately
# before `os.replace` — the window where a naive writer would have
# already clobbered the target.  Production code never sets these.
PRE_REPLACE_HOOK: list = [None]


def atomic_replace(path: str, write_fn: Callable[[str], None]) -> None:
    """Write a file atomically: `write_fn(tmp_path)` then `os.replace`.

    `write_fn` must create `tmp_path` itself (open the exact path it is
    given — e.g. `open(tmp, "wb")` for numpy savers, which would append
    ".npz" to a bare filename).  The tmp file lives next to the target
    so the final rename never crosses a filesystem boundary.
    """
    path = os.fspath(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        write_fn(tmp)
        if PRE_REPLACE_HOOK[0] is not None:
            PRE_REPLACE_HOOK[0](path, tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def atomic_write_json(path: str, obj) -> None:
    """`json.dump` through `atomic_replace` (manifests, cache sidecars)."""
    def _write(tmp):
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1, sort_keys=True)
    atomic_replace(path, _write)
