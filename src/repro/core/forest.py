"""Random Forest manager (paper §2.5).

"To train a Random Forest, the manager queries in parallel the tree
builders.  This query contains the index of the requested tree (the tree
index is used in the seeding, §2.2) as well as a list of splitters ..."

The manager here is the host loop: each tree is trained by `tree.build_tree`
(the tree-builder) against the shared presorted dataset (the splitters'
columns).  Trees are independent — on a real cluster DRF trains them in
parallel on replicated splitters; we expose `predict`, OOB scoring and
distributed feature importance on top.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, presort, tree as tree_lib
from repro.core.dataset import TabularDataset


@dataclasses.dataclass
class RandomForest:
    params: tree_lib.TreeParams
    num_trees: int = 10
    seed: int = 0

    trees: list = dataclasses.field(default_factory=list)
    level_stats: list = dataclasses.field(default_factory=list)
    num_classes: int = 2
    m: int = 0
    m_num: int = 0

    # ------------------------------------------------------------------
    def fit(self, ds: TabularDataset, collect_stats: bool = False,
            supersplit_fn=None) -> "RandomForest":
        ds.validate()
        self.num_classes = ds.num_classes
        self.m, self.m_num = ds.m, ds.m_num
        # §2.1 dataset preparation: presort once, reuse for every tree.
        if ds.m_num:
            sorted_idx = presort.presort_columns(ds.num)
            sorted_vals = presort.gather_sorted(ds.num, sorted_idx)
        else:
            sorted_idx = jnp.zeros((0, ds.n), jnp.int32)
            sorted_vals = jnp.zeros((0, ds.n), jnp.float32)
        self.trees, self.level_stats = [], []
        for t in range(self.num_trees):
            tr, stats = tree_lib.build_tree(
                num=ds.num, cat=ds.cat, labels=ds.labels,
                sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                arities=ds.arities, num_classes=ds.num_classes,
                params=self.params, seed=self.seed, tree_idx=t,
                collect_stats=collect_stats, supersplit_fn=supersplit_fn)
            self.trees.append(tr)
            self.level_stats.append(stats)
        return self

    # ------------------------------------------------------------------
    def predict_proba(self, num, cat, up_to: Optional[int] = None) -> jnp.ndarray:
        assert self.trees, "fit first"
        acc = None
        for tr in self.trees[:up_to]:
            p = tr.predict_raw(jnp.asarray(num, jnp.float32), jnp.asarray(cat, jnp.int32))
            acc = p if acc is None else acc + p
        return acc / len(self.trees[:up_to])

    def predict(self, num, cat) -> jnp.ndarray:
        p = self.predict_proba(num, cat)
        if self.params.task == "classification":
            return jnp.argmax(p, axis=-1)
        return p[:, 0]

    # ------------------------------------------------------------------
    def oob_score(self, ds: TabularDataset) -> float:
        """Out-of-bag accuracy using the seeded bagging (zero extra state)."""
        n = ds.n
        correct = np.zeros(n)
        counted = np.zeros(n)
        for t, tr in enumerate(self.trees):
            w = np.asarray(bagging.bag_counts(self.seed, t, n, self.params.bagging))
            oob = w == 0
            if not oob.any():
                continue
            p = np.asarray(tr.predict_raw(ds.num, ds.cat))
            pred = p.argmax(-1)
            correct[oob] += pred[oob] == np.asarray(ds.labels)[oob]
            counted[oob] += 1
        mask = counted > 0
        return float((correct[mask] / counted[mask]).mean()) if mask.any() else float("nan")

    # ------------------------------------------------------------------
    def feature_importances(self) -> np.ndarray:
        """Mean decrease in impurity, computed per-splitter then merged —
        the paper's "distributed computing of feature importance"."""
        from repro.core import importance
        return importance.mdi_importance(self.trees, self.m)

    def auc(self, ds: TabularDataset) -> float:
        """Binary AUC (the paper's headline metric on Leo / Fig. 1)."""
        assert self.num_classes == 2
        scores = np.asarray(self.predict_proba(ds.num, ds.cat))[:, 1]
        y = np.asarray(ds.labels)
        order = np.argsort(scores, kind="stable")
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(1, len(y) + 1)
        # average ranks over ties
        s_sorted = scores[order]
        uniq, inv, cnts = np.unique(s_sorted, return_inverse=True, return_counts=True)
        start = np.concatenate([[0], np.cumsum(cnts)[:-1]])
        avg = start + (cnts + 1) / 2.0
        ranks[order] = avg[inv]
        n1 = (y == 1).sum()
        n0 = (y == 0).sum()
        if n1 == 0 or n0 == 0:
            return float("nan")
        u = ranks[y == 1].sum() - n1 * (n1 + 1) / 2.0
        return float(u / (n1 * n0))
