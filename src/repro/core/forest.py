"""Random Forest manager (paper §2.5) + stacked forest inference.

"To train a Random Forest, the manager queries in parallel the tree
builders.  This query contains the index of the requested tree (the tree
index is used in the seeding, §2.2) as well as a list of splitters ..."

The manager here is the host loop: each tree is trained by `tree.build_tree`
(the tree-builder) against the shared presorted dataset (the splitters'
columns).  Trees are independent — on a real cluster DRF trains them in
parallel on replicated splitters; we expose `predict`, OOB scoring and
distributed feature importance on top.

Inference is batched over the whole forest: `fit` packs every tree into one
set of padded flat arrays (`PackedForest`) and `predict_proba` is a single
jitted vmap-over-trees descent — one device program for a 100-tree forest
instead of a per-tree Python loop with a retrace per tree (the per-tree
`iters` used to be a distinct static argument for every tree).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, presort, tree as tree_lib
from repro.core.dataset import TabularDataset
from repro.core.level.engines import SplitEngine


# ---------------------------------------------------------------------------
# Stacked forest inference
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedForest:
    """All trees of a forest in one set of padded flat arrays.

    `pack_trees` pads every tree to the forest maxima — N = max node count,
    V = max categorical arity, C = max value width — and stacks them, so a
    T-tree forest is six device arrays with a leading tree axis (shapes
    below) instead of T Python objects.  This is what makes whole-forest
    inference ONE jitted program (`RandomForest.predict_proba`): a vmap
    over the tree axis of a fori_loop descent with the single static
    iteration bound `iters`.

    Nodes beyond a tree's `num_nodes` are padding leaves (feature −1,
    value 0); they are unreachable because the descent starts at node 0 and
    leaves are absorbing.  Feature ids < `m_num` are numeric (threshold
    rule x <= thr), the rest categorical (membership in `cat_mask`).
    """
    feature: jnp.ndarray     # (T, N) int32; -1 = leaf
    threshold: jnp.ndarray   # (T, N) float32
    is_cat: jnp.ndarray      # (T, N) bool
    cat_mask: jnp.ndarray    # (T, N, V) bool
    children: jnp.ndarray    # (T, N, 2) int32
    value: jnp.ndarray       # (T, N, C) float32
    m_num: int
    iters: int               # max depth over trees + 1 (static descent bound)

    FORMAT_VERSION = 1       # bump on any array-layout change

    @property
    def num_trees(self) -> int:
        return int(self.feature.shape[0])

    # -- stable export path (ROADMAP "Serving") ------------------------
    _ARRAYS = ("feature", "threshold", "is_cat", "cat_mask", "children",
               "value")

    def save(self, path) -> None:
        """Serialize to ONE .npz file with a format-version field.

        The file is self-contained: `PackedForest.load` + `predict_proba`
        is a full batched-inference stack with no Tree objects, no
        training code path, and no pickle (plain npz arrays only) — the
        stable boundary a serving process loads across repo versions.

        Written atomically (tmp + `os.replace`, DESIGN.md §9): a crash
        mid-save leaves either the previous complete model or the new
        one, never a truncated .npz a server would fail to load.
        """
        import os

        from repro.core import atomicio
        p = os.fspath(path)
        if not p.endswith(".npz"):
            p += ".npz"          # numpy's suffix rule, applied up front
        arrays = dict(
            format_version=np.int32(self.FORMAT_VERSION),
            m_num=np.int32(self.m_num), iters=np.int32(self.iters),
            **{k: np.asarray(getattr(self, k)) for k in self._ARRAYS})
        atomicio.atomic_replace(
            p, lambda tmp: np.savez_compressed(open(tmp, "wb"), **arrays))

    @classmethod
    def load(cls, path) -> "PackedForest":
        """Load an .npz written by `save` (version-checked).

        Accepts the same path string `save` was given: numpy appends
        ".npz" to suffix-less filenames at save time, so retry with it.
        """
        import os
        p = os.fspath(path)
        if not os.path.exists(p) and not p.endswith(".npz"):
            p += ".npz"
        with np.load(p) as z:
            version = int(z["format_version"])
            if version != cls.FORMAT_VERSION:
                raise ValueError(
                    f"PackedForest format v{version} not supported "
                    f"(this build reads v{cls.FORMAT_VERSION})")
            return cls(m_num=int(z["m_num"]), iters=int(z["iters"]),
                       **{k: jnp.asarray(z[k]) for k in cls._ARRAYS})

    def predict_proba(self, num, cat, reduce_mean: bool = True):
        """Batched inference straight off the packed arrays: ONE jitted
        call for the whole forest — (B, C) forest mean, or (T, B, C) with
        `reduce_mean=False` (see `examples/forest_export.py`)."""
        return _forest_predict(
            self.feature, self.threshold, self.is_cat, self.cat_mask,
            self.children, self.value, jnp.asarray(num, jnp.float32),
            jnp.asarray(cat, jnp.int32), self.m_num, self.iters,
            reduce_mean)


def pack_trees(trees: list) -> PackedForest:
    """Pad each tree's flat arrays to the forest maximum and stack."""
    assert trees
    T = len(trees)
    N = max(t.num_nodes for t in trees)
    V = max(t.cat_mask.shape[1] for t in trees)
    C = max(t.value.shape[1] for t in trees)
    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    is_cat = np.zeros((T, N), bool)
    cat_mask = np.zeros((T, N, V), bool)
    children = np.full((T, N, 2), -1, np.int32)
    value = np.zeros((T, N, C), np.float32)
    for t, tr in enumerate(trees):
        k = tr.num_nodes
        feature[t, :k] = tr.feature
        threshold[t, :k] = tr.threshold
        is_cat[t, :k] = tr.is_cat
        cat_mask[t, :k, :tr.cat_mask.shape[1]] = tr.cat_mask
        children[t, :k] = tr.children
        value[t, :k, :tr.value.shape[1]] = tr.value
    iters = max(int(t.depth.max()) for t in trees) + 1
    return PackedForest(
        feature=jnp.asarray(feature), threshold=jnp.asarray(threshold),
        is_cat=jnp.asarray(is_cat), cat_mask=jnp.asarray(cat_mask),
        children=jnp.asarray(children), value=jnp.asarray(value),
        m_num=trees[0].m_num, iters=iters)


# trace counter: tests assert predict_proba compiles ONCE for a whole
# forest (no per-tree retraces) — the body below runs only at trace time
_PREDICT_TRACES = [0]


def _forest_predict_impl(feature, threshold, is_cat, cat_mask, children,
                         value, num, cat, m_num, iters, reduce_mean):
    _PREDICT_TRACES[0] += 1
    B = num.shape[0] if num.size else cat.shape[0]

    def one_tree(f, th, ic, cm, ch, val):
        node = jnp.zeros((B,), jnp.int32)

        def body(_, node):
            ff = f[node]
            leaf = ff < 0
            jn = jnp.clip(ff, 0, max(m_num - 1, 0))
            jc = jnp.clip(ff - m_num, 0, max(cat.shape[1] - 1, 0))
            xnum = (jnp.take_along_axis(num, jn[:, None], 1)[:, 0]
                    if num.size else jnp.zeros((B,), jnp.float32))
            xcat = (jnp.take_along_axis(cat, jc[:, None], 1)[:, 0]
                    if cat.size else jnp.zeros((B,), jnp.int32))
            go_left = jnp.where(ic[node], cm[node, xcat], xnum <= th[node])
            nxt = jnp.where(go_left, ch[node, 0], ch[node, 1])
            return jnp.where(leaf, node, nxt)

        node = jax.lax.fori_loop(0, iters, body, node)
        return val[node]                                      # (B, C)

    preds = jax.vmap(one_tree)(feature, threshold, is_cat, cat_mask,
                               children, value)               # (T, B, C)
    return preds.mean(axis=0) if reduce_mean else preds


_forest_predict = jax.jit(
    _forest_predict_impl,
    static_argnames=("m_num", "iters", "reduce_mean"))


@dataclasses.dataclass
class RandomForest:
    """The paper's DRF: an exact Random Forest trained level by level.

    Construction params:
      params:     `tree.TreeParams` — depth/impurity/backend etc.; see its
                  fields for the paper hyper-parameters (m', min_records,
                  USB, Sprint pruning).  `split_mode="hist"` trains the
                  PLANET-style approximate baseline (<= num_bins threshold
                  buckets per numeric column, DESIGN.md §6) on the same
                  fused level machinery; `"exact"` (default) is the
                  paper's exact search.
      num_trees:  forest size T.
      seed:       forest seed; ALL randomness (bagging, candidate features)
                  is a pure function of (seed, tree index) — the paper's
                  zero-communication seeding (§2.2).
      tree_batch: how many trees to train per batched device program
                  (DESIGN.md §3).  None (default) picks a memory-bounded
                  batch automatically; 1 forces the per-tree builder; any
                  k > 1 trains the forest in ⌈T/k⌉ chunks, each chunk
                  issuing ONE jitted program per depth level for all its
                  trees.  Trees are bit-identical for every choice.

    `fit(ds)` trains on a `TabularDataset` and packs the trees into a
    `PackedForest`, after which `predict` / `predict_proba` (forest mean,
    (B, C)) and `predict_proba_per_tree` ((T, B, C)) are each ONE jitted
    device call regardless of T.  `oob_score`, `auc`, and
    `feature_importances` are the paper's evaluation utilities.
    """

    params: tree_lib.TreeParams
    num_trees: int = 10
    seed: int = 0
    tree_batch: Optional[int] = None

    trees: list = dataclasses.field(default_factory=list)
    level_stats: list = dataclasses.field(default_factory=list)
    num_classes: int = 2
    m: int = 0
    m_num: int = 0
    packed: Optional[PackedForest] = None

    # ------------------------------------------------------------------
    def _resolve_tree_batch(self, ds: TabularDataset) -> int:
        """Trees per batched level program (1 = per-tree builder).

        The auto heuristic bounds the batched step's largest row-indexed
        intermediate (T·m_num·n elements, ~256 MB f32) and caps at 16 —
        past that the programs are compute-bound and batching wider only
        adds memory pressure.
        """
        if self.tree_batch is not None:
            return max(1, min(int(self.tree_batch), self.num_trees))
        per_tree = max(1, max(ds.m_num, 1) * ds.n)
        return int(max(1, min(self.num_trees, 16, (1 << 26) // per_tree)))

    def fit(self, ds: TabularDataset, collect_stats: bool = False,
            supersplit_fn=None, engine=None,
            cat_engine=None) -> "RandomForest":
        """Train the forest; one batched device program per depth level.

        Trees are chunked into `tree_batch`-sized groups and each group is
        built by `tree.build_forest` — the fused level step vmapped over
        the tree axis.  EVERY mode runs through that one plan: local or
        mesh-sharded engines (`engine=` / `cat_engine=`, see
        `repro.core.level`), exact or hist, with or without Sprint pruning
        (`prune_closed_frac`).  The only fallback to the per-tree
        `tree.build_tree` loop is a LEGACY bare `supersplit_fn` closure
        (the pre-engine API), which composes with neither the tree-axis
        vmap nor the batch-native protocol — passing one emits a
        UserWarning and forces `tree_batch=1`; pass a `SplitEngine`
        instead to keep tree batching.  Trees are identical either way,
        only the dispatch count changes.
        """
        from repro.core.dataset import RowSource
        if isinstance(ds, RowSource):
            raise TypeError(
                "fit() trains from a fully materialized TabularDataset; "
                "for a RowSource (out-of-core bin cache) use "
                "fit_streamed(source)")
        ds.validate()
        self.num_classes = ds.num_classes
        self.m, self.m_num = ds.m, ds.m_num
        # §2.1 dataset preparation: presort once, reuse for every tree.
        if ds.m_num:
            sorted_idx = presort.presort_columns(ds.num)
            sorted_vals = presort.gather_sorted(ds.num, sorted_idx)
        else:
            sorted_idx = jnp.zeros((0, ds.n), jnp.int32)
            sorted_vals = jnp.zeros((0, ds.n), jnp.float32)
        kw = dict(num=ds.num, cat=ds.cat, labels=ds.labels,
                  sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                  arities=ds.arities, num_classes=ds.num_classes,
                  params=self.params, seed=self.seed,
                  collect_stats=collect_stats,
                  engine=engine, cat_engine=cat_engine)
        if self.params.split_mode == "hist" and ds.m_num:
            # hist mode: quantize once per forest (the PLANET-style fixed
            # bucket budget), shared by every tree/level like the presort
            bin_of, bin_edges = presort.quantize(ds.num, sorted_vals,
                                                 self.params.num_bins)
            kw.update(bin_of=bin_of, bin_edges=bin_edges)
        if supersplit_fn is not None and engine is not None:
            raise ValueError(
                "pass either engine= (a SplitEngine) or supersplit_fn=, "
                "not both — one of them would be silently ignored")
        if isinstance(supersplit_fn, SplitEngine):
            # the engine API replaces supersplit_fn; accept it here too
            kw["engine"] = supersplit_fn
            supersplit_fn = None
        tb = self._resolve_tree_batch(ds)
        if supersplit_fn is not None:
            warnings.warn(
                "legacy supersplit_fn closures force the per-tree builder "
                "(tree_batch=1, one level program per depth PER TREE); "
                "pass a repro.core.level SplitEngine (engine=...) to keep "
                "the batched one-program-per-depth path",
                UserWarning, stacklevel=2)
            tb = 1                      # per-tree-only configuration
        self.trees, self.level_stats = [], []
        if tb > 1:
            for lo in range(0, self.num_trees, tb):
                trees, stats = tree_lib.build_forest(
                    tree_indices=range(lo, min(lo + tb, self.num_trees)),
                    **kw)
                self.trees.extend(trees)
                self.level_stats.extend(stats)
        else:
            for t in range(self.num_trees):
                tr, stats = tree_lib.build_tree(
                    tree_idx=t, supersplit_fn=supersplit_fn, **kw)
                self.trees.append(tr)
                self.level_stats.append(stats)
        self.packed = pack_trees(self.trees)      # stacked inference arrays
        return self

    def fit_streamed(self, source, collect_stats: bool = False,
                     engine=None, checkpoint_dir: Optional[str] = None,
                     checkpoint_every: int = 1,
                     resume: bool = False) -> "RandomForest":
        """Train the forest out-of-core from a `dataset.RowSource`.

        Same trees as `fit` on the equivalently quantized in-memory
        dataset (bit-identical node for node, tests/test_stream_parity.py)
        but the per-row state stays host-resident — the level programs see
        only fixed-shape chunks of the bit-packed bin cache, so peak
        device memory is bounded by `source.chunk_size`, not n.  Hist
        split mode + classification + numeric columns only (the
        `tree.build_forest_streamed` restrictions).

        Fault tolerance (DESIGN.md §9): `checkpoint_dir=` snapshots the
        in-flight tree batch's host state every `checkpoint_every`
        levels and commits each finished batch, all atomically;
        `resume=True` skips committed batches, restores the in-flight
        one at its last snapshotted level, and finishes the forest
        bit-identically to an uninterrupted fit.  Resuming against a
        different source / params / seed raises
        `checkpoint.CheckpointMismatchError`.  Under multi-host
        sharding only process 0 writes; every host fingerprint-checks.
        """
        from repro.core.dataset import RowSource, TabularDataset
        if isinstance(source, TabularDataset):
            raise TypeError(
                "fit_streamed() trains from a RowSource; wrap the dataset "
                "with ArrayRowSource.from_dataset(ds, num_bins) (or use "
                "plain fit(ds))")
        if not isinstance(source, RowSource):
            raise TypeError(f"expected a dataset.RowSource, got "
                            f"{type(source).__name__}")
        self.num_classes = source.num_classes
        self.m = self.m_num = source.m_num
        ck = None
        if checkpoint_dir is not None:
            from repro.core import checkpoint as checkpoint_lib
            ck = checkpoint_lib.StreamCheckpointer(checkpoint_dir,
                                                   every=checkpoint_every)
            ck.prepare(source=source, params=self.params, seed=self.seed,
                       resume=resume)
        tb = (max(1, min(int(self.tree_batch), self.num_trees))
              if self.tree_batch is not None else min(self.num_trees, 16))
        self.trees, self.level_stats = [], []
        for lo in range(0, self.num_trees, tb):
            trees, stats = tree_lib.build_forest_streamed(
                source=source,
                tree_indices=range(lo, min(lo + tb, self.num_trees)),
                params=self.params, seed=self.seed,
                collect_stats=collect_stats, engine=engine,
                resume=resume, _checkpointer=ck)
            self.trees.extend(trees)
            self.level_stats.extend(stats)
        self.packed = pack_trees(self.trees)
        return self

    # ------------------------------------------------------------------
    def _packed_forest(self, up_to: Optional[int] = None) -> PackedForest:
        assert self.trees, "fit first"
        if self.packed is None or self.packed.num_trees != len(self.trees):
            self.packed = pack_trees(self.trees)
        pk = self.packed
        if up_to is not None and up_to < pk.num_trees:
            pk = dataclasses.replace(
                pk, feature=pk.feature[:up_to], threshold=pk.threshold[:up_to],
                is_cat=pk.is_cat[:up_to], cat_mask=pk.cat_mask[:up_to],
                children=pk.children[:up_to], value=pk.value[:up_to])
        return pk

    def predict_proba(self, num, cat, up_to: Optional[int] = None) -> jnp.ndarray:
        """Forest-averaged distributions in ONE jitted call (vmap over the
        packed trees — no per-tree Python loop, no per-tree retrace)."""
        pk = self._packed_forest(up_to)
        return _forest_predict(
            pk.feature, pk.threshold, pk.is_cat, pk.cat_mask, pk.children,
            pk.value, jnp.asarray(num, jnp.float32), jnp.asarray(cat, jnp.int32),
            pk.m_num, pk.iters, True)

    def predict_proba_per_tree(self, num, cat) -> jnp.ndarray:
        """(T, B, C) per-tree predictions, one jitted call (OOB, analysis)."""
        pk = self._packed_forest()
        return _forest_predict(
            pk.feature, pk.threshold, pk.is_cat, pk.cat_mask, pk.children,
            pk.value, jnp.asarray(num, jnp.float32), jnp.asarray(cat, jnp.int32),
            pk.m_num, pk.iters, False)

    def predict(self, num, cat) -> jnp.ndarray:
        p = self.predict_proba(num, cat)
        if self.params.task == "classification":
            return jnp.argmax(p, axis=-1)
        return p[:, 0]

    # ------------------------------------------------------------------
    def oob_score(self, ds: TabularDataset) -> float:
        """Out-of-bag accuracy using the seeded bagging (zero extra state)."""
        n = ds.n
        correct = np.zeros(n)
        counted = np.zeros(n)
        oob_masks = [
            np.asarray(bagging.bag_counts(self.seed, t, n,
                                          self.params.bagging)) == 0
            for t in range(len(self.trees))]
        if not any(m.any() for m in oob_masks):   # e.g. bagging == "none"
            return float("nan")
        # one device program for all trees; argmax on device so only the
        # (T, B) class ids cross to the host
        preds = np.asarray(jnp.argmax(
            self.predict_proba_per_tree(ds.num, ds.cat), axis=-1))
        labels = np.asarray(ds.labels)
        for t, oob in enumerate(oob_masks):
            if not oob.any():
                continue
            correct[oob] += preds[t][oob] == labels[oob]
            counted[oob] += 1
        mask = counted > 0
        return float((correct[mask] / counted[mask]).mean()) if mask.any() else float("nan")

    # ------------------------------------------------------------------
    def feature_importances(self) -> np.ndarray:
        """Mean decrease in impurity, computed per-splitter then merged —
        the paper's "distributed computing of feature importance"."""
        from repro.core import importance
        return importance.mdi_importance(self.trees, self.m)

    def auc(self, ds: TabularDataset) -> float:
        """Binary AUC (the paper's headline metric on Leo / Fig. 1)."""
        assert self.num_classes == 2
        scores = np.asarray(self.predict_proba(ds.num, ds.cat))[:, 1]
        y = np.asarray(ds.labels)
        order = np.argsort(scores, kind="stable")
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(1, len(y) + 1)
        # average ranks over ties
        s_sorted = scores[order]
        uniq, inv, cnts = np.unique(s_sorted, return_inverse=True, return_counts=True)
        start = np.concatenate([[0], np.cumsum(cnts)[:-1]])
        avg = start + (cnts + 1) / 2.0
        ranks[order] = avg[inv]
        n1 = (y == 1).sum()
        n0 = (y == 0).sum()
        if n1 == 0 or n0 == 0:
            return float("nan")
        u = ranks[y == 1].sum() - n1 * (n1 + 1) / 2.0
        return float(u / (n1 * n0))
