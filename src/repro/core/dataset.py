"""Tabular dataset container for DRF (paper §2.1).

The paper's datasets mix numerical and categorical columns (Leo: 3 numerical
+ 69 categorical, arities 2..10'000). We keep the two groups in separate
dense arrays; feature ids 0..m_num-1 are numerical, m_num..m-1 categorical.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import random
import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

logger = logging.getLogger("repro.core.stream")


@dataclasses.dataclass
class TabularDataset:
    """A dataset of n rows: numerical (f32) and categorical (i32) columns."""

    num: jnp.ndarray            # (n, m_num) float32
    cat: jnp.ndarray            # (n, m_cat) int32, values in [0, arity_j)
    labels: jnp.ndarray         # (n,) int32 (classification) / float32 (regression)
    arities: tuple[int, ...]    # per categorical column
    num_classes: int = 2        # ignored for regression
    task: str = "classification"  # or "regression"

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    @property
    def m_num(self) -> int:
        return int(self.num.shape[1]) if self.num.size else 0

    @property
    def m_cat(self) -> int:
        return int(self.cat.shape[1]) if self.cat.size else 0

    @property
    def m(self) -> int:
        return self.m_num + self.m_cat

    @property
    def max_arity(self) -> int:
        return max(self.arities) if self.arities else 0

    def validate(self) -> None:
        assert self.num.ndim == 2 and self.cat.ndim == 2
        assert self.num.shape[0] == self.cat.shape[0] == self.labels.shape[0]
        assert len(self.arities) == self.m_cat
        if self.task == "classification":
            assert jnp.issubdtype(self.labels.dtype, jnp.integer)

    def quantize(self, num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """PLANET-style threshold buckets for `split_mode="hist"`.

        Standalone convenience (presorts internally — the fits reuse their
        own presort and call `presort.quantize` directly): buckets every
        numeric column into <= num_bins equi-depth quantile buckets.
        Returns (bin_of (m_num, n) bit-packed bucket ids —
        `presort.bin_dtype`: uint8 for <= 256 bins, uint16 past — and
        edges (m_num, num_bins) float32); both empty when there are no
        numeric columns.  Useful for feeding precomputed bucket state to
        `tree.build_tree`/`build_forest` or for inspecting the quantizer
        in tests.
        """
        from repro.core import presort
        if not self.m_num:
            return (jnp.zeros((0, self.n), presort.bin_dtype(num_bins)),
                    jnp.zeros((0, num_bins), jnp.float32))
        sorted_idx = presort.presort_columns(self.num)
        sorted_vals = presort.gather_sorted(self.num, sorted_idx)
        return presort.quantize(self.num, sorted_vals, num_bins)


def from_numpy(
    num: np.ndarray | None,
    cat: np.ndarray | None,
    labels: np.ndarray,
    arities: Sequence[int] | None = None,
    task: str = "classification",
) -> TabularDataset:
    n = labels.shape[0]
    num = np.zeros((n, 0), np.float32) if num is None else np.asarray(num, np.float32)
    cat = np.zeros((n, 0), np.int32) if cat is None else np.asarray(cat, np.int32)
    if arities is None:
        arities = tuple(int(cat[:, j].max()) + 1 if n else 2 for j in range(cat.shape[1]))
    if task == "classification":
        labels = np.asarray(labels, np.int32)
        num_classes = int(labels.max()) + 1 if n else 2
    else:
        labels = np.asarray(labels, np.float32)
        num_classes = 0
    # Columns stay HOST numpy here: `jnp.asarray` on a memory-mapped array
    # would fault the whole file into device memory, defeating mmap inputs.
    # The fit entry points (`tree.build_tree`/`build_forest`) device-put
    # once when training actually starts, and `RowSource` backends slice
    # host blocks without ever materializing n rows on device.
    ds = TabularDataset(
        num=num, cat=cat, labels=labels,
        arities=tuple(int(a) for a in arities), num_classes=max(num_classes, 2),
        task=task,
    )
    ds.validate()
    return ds


# ---------------------------------------------------------------------------
# Out-of-core row streams (DESIGN.md §8)
# ---------------------------------------------------------------------------
#
# After PR 5 the bit-packed bin cache is the ONLY per-row numeric state a
# hist level program reads, so training can stream fixed-shape row blocks
# of (bins, labels, weights, leaf-ids) through the accumulator instead of
# holding (m_num, n) on device.  A `RowSource` owns the host-resident
# pieces of that state — the bin cache (in memory or memory-mapped on
# disk), the int32 labels, and the decoded float32 edges — and hands out
# contiguous column blocks; the streamed driver
# (`tree.build_forest_streamed`) owns weights and leaf ids.

class StreamReadError(OSError):
    """A chunk read kept failing after the retry budget (DESIGN.md §9).

    Raised by `read_with_retry` once every backoff attempt has been
    exhausted; the streamed driver flushes its held level checkpoint
    before letting this escape, so a resume restarts at the last
    completed level rather than from scratch."""


class CacheIntegrityError(RuntimeError):
    """An on-disk bin cache disagrees with its sidecar metadata.

    A truncated, dtype-mismatched, or swapped `.npy` cache would
    otherwise train garbage trees silently — `MemmapRowSource` verifies
    the sidecar written by `build()` before the first read."""


def read_with_retry(fn, *args, attempts: int = 4, base_delay: float = 0.05,
                    max_delay: float = 2.0, jitter: float = 0.5,
                    sleep=time.sleep):
    """Call `fn(*args)` retrying transient `OSError`s with backoff.

    Delays grow exponentially from `base_delay` (capped at `max_delay`)
    with up to `jitter`x multiplicative random jitter — the standard
    recipe against thundering-herd re-reads on a shared filesystem.
    Each failure logs a warning; the final one raises `StreamReadError`
    (chained).  Retrying is SAFE for bit-parity: `bins_block` /
    `bins_take` are pure reads, so a retried chunk is byte-identical to
    a first-try chunk.  Integrity failures (`CacheIntegrityError`) and
    an inner `StreamReadError` are not transient and propagate
    immediately."""
    for attempt in range(1, max(1, int(attempts)) + 1):
        try:
            return fn(*args)
        except StreamReadError:
            raise
        except OSError as e:
            if attempt >= attempts:
                raise StreamReadError(
                    f"stream read failed after {attempts} attempts: "
                    f"{getattr(fn, '__qualname__', fn)!s}: {e}") from e
            delay = min(max_delay, base_delay * (2.0 ** (attempt - 1)))
            delay *= 1.0 + jitter * random.random()
            logger.warning(
                "transient stream read failure (attempt %d/%d): %s — "
                "retrying in %.3fs", attempt, attempts, e, delay)
            sleep(delay)


class RowSource:
    """Host-resident binned rows for streamed hist training.

    Concrete backends provide `bins_block(lo, hi)` (contiguous slice) and
    `bins_take(idx)` (gather, used after host-side pruning compacts the
    active row set).  Only hist mode streams: exact mode needs the full
    presort ("exact needs the presort; only hist streams" — the fit entry
    points enforce this)."""

    #: retry budget for `tree.build_forest_streamed` chunk reads — every
    #: `bins_block`/`bins_take` call in the streamed driver goes through
    #: `read_with_retry` with these knobs (transient `OSError`s retried
    #: with exponential backoff + jitter, then `StreamReadError`).
    retry_attempts: int = 4
    retry_base_delay: float = 0.05
    retry_max_delay: float = 2.0
    retry_sleep = staticmethod(time.sleep)

    def __init__(self, edges: np.ndarray, labels: np.ndarray, *,
                 num_classes: int, task: str = "classification",
                 chunk_size: int = 1 << 16):
        self.edges = np.ascontiguousarray(edges, np.float32)   # (m_num, B)
        self.labels = np.ascontiguousarray(labels)             # (n,) host
        self.num_classes = int(num_classes)
        self.task = task
        self.chunk_size = int(chunk_size)
        assert self.chunk_size >= 1

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    @property
    def m_num(self) -> int:
        return int(self.edges.shape[0])

    @property
    def num_bins(self) -> int:
        return int(self.edges.shape[1])

    def bins_block(self, lo: int, hi: int) -> np.ndarray:
        """Contiguous bin-cache block: (m_num, hi-lo) packed host array."""
        raise NotImplementedError

    def bins_take(self, idx: np.ndarray) -> np.ndarray:
        """Gathered bin-cache block for row indices idx: (m_num, len(idx))."""
        raise NotImplementedError


class ArrayRowSource(RowSource):
    """RowSource over an in-memory (m_num, n) bin cache."""

    def __init__(self, bins: np.ndarray, edges: np.ndarray,
                 labels: np.ndarray, **kw):
        super().__init__(edges, labels, **kw)
        self.bins = np.ascontiguousarray(bins)
        assert self.bins.shape == (self.m_num, self.n)

    @classmethod
    def from_dataset(cls, ds: TabularDataset, num_bins: int,
                     chunk_size: int | None = None) -> "ArrayRowSource":
        """Quantize a numeric-only dataset into a streamable source.

        Uses the same `TabularDataset.quantize` recipe as the in-memory
        fit, so the edges (and therefore every downstream decision) are
        bit-equal to `RandomForest.fit(ds)` in hist mode."""
        assert ds.m_cat == 0, "streaming sources are numeric-only"
        bins, edges = ds.quantize(num_bins)
        kw = {} if chunk_size is None else {"chunk_size": chunk_size}
        return cls(np.asarray(bins), np.asarray(edges), np.asarray(ds.labels),
                   num_classes=ds.num_classes, task=ds.task, **kw)

    def bins_block(self, lo: int, hi: int) -> np.ndarray:
        return self.bins[:, lo:hi]

    def bins_take(self, idx: np.ndarray) -> np.ndarray:
        return self.bins[:, idx]


class MemmapRowSource(RowSource):
    """RowSource over an on-disk bin cache (.npy, row-major (n, m_num)).

    The cache is stored ROW-major so a chunk of rows is one contiguous
    file range — `bins_block` reads [lo:hi) and transposes to the
    (m_num, c) layout the level program consumes.  Built from a chunked
    float stream by `build` (3 radix-select passes for the edges + 1
    binning pass), so no full float32 column ever exists in memory."""

    def __init__(self, path: str, edges: np.ndarray, labels: np.ndarray, **kw):
        super().__init__(edges, labels, **kw)
        self.path = str(path)
        self._mm = None

    # -- cache integrity (DESIGN.md §9) --------------------------------
    @staticmethod
    def meta_path(path: str) -> str:
        return f"{path}.meta.json"

    def _expected_meta(self) -> dict:
        return {
            "format_version": 1,
            "n": self.n,
            "m_num": self.m_num,
            "dtype": np.dtype(np.uint8 if self.num_bins <= 256
                              else np.uint16).name,
            "num_bins": self.num_bins,
            "edges_sha256": hashlib.sha256(
                np.ascontiguousarray(self.edges, np.float32).tobytes()
            ).hexdigest(),
        }

    def _verify_cache(self, mm: np.ndarray) -> None:
        """Check the cache against its sidecar before the first read.

        Caches predating the sidecar (or hand-built ones) get only the
        shape check; a present-but-disagreeing sidecar, a truncated
        file, or a dtype change raises `CacheIntegrityError` instead of
        silently training garbage trees."""
        if mm.shape != (self.n, self.m_num):
            raise CacheIntegrityError(
                f"bin cache {self.path!r} has shape {tuple(mm.shape)}, "
                f"expected (n, m_num) = ({self.n}, {self.m_num}) — the "
                f"file is truncated or belongs to a different dataset")
        mp = self.meta_path(self.path)
        if not os.path.exists(mp):
            # legacy / hand-built cache: only the shape check applies.
            # (`build()` writes the sidecar LAST, so this also catches a
            # build that was killed mid-binning — warn, don't trust it.)
            logger.warning(
                "bin cache %s has no sidecar metadata (%s) — integrity "
                "cannot be verified; rebuild with MemmapRowSource.build "
                "to get content checks", self.path, mp)
            return
        try:
            with open(mp) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CacheIntegrityError(
                f"unreadable bin-cache sidecar {mp!r}: {e}") from e
        expect = self._expected_meta()
        bad = [k for k in expect if meta.get(k) != expect[k]]
        if np.dtype(mm.dtype).name != meta.get("dtype"):
            bad.append("dtype(file)")   # cache rewritten at another width
        if bad:
            raise CacheIntegrityError(
                f"bin cache {self.path!r} disagrees with its sidecar "
                f"{mp!r} (mismatched: {', '.join(bad) or 'dtype'}) — the "
                f"cache was rebuilt, truncated, or swapped since "
                f"`build()`; rebuild it with MemmapRowSource.build")

    def _cache(self) -> np.ndarray:
        if self._mm is None:
            try:
                mm = np.load(self.path, mmap_mode="r")
            except (OSError, ValueError) as e:
                raise CacheIntegrityError(
                    f"bin cache {self.path!r} failed to open as a .npy "
                    f"memmap: {e}") from e
            self._verify_cache(mm)
            self._mm = mm
        return self._mm

    def bins_block(self, lo: int, hi: int) -> np.ndarray:
        return np.ascontiguousarray(self._cache()[lo:hi].T)

    def bins_take(self, idx: np.ndarray) -> np.ndarray:
        return np.ascontiguousarray(self._cache()[idx].T)

    @classmethod
    def build(cls, chunks, n: int, labels: np.ndarray, *, num_bins: int,
              path: str, num_classes: int | None = None,
              task: str = "classification",
              chunk_size: int = 1 << 16) -> "MemmapRowSource":
        """Quantize + bin a chunked float stream straight to disk.

        `chunks` is a re-iterable callable yielding (c, m_num) float32 row
        blocks in row order (called 4 times: 3 edge-finding passes + 1
        binning pass).  Peak memory is one block + O(m_num · num_bins)."""
        from repro.core import presort
        first = next(iter(chunks()))
        m_num = int(first.shape[1])
        edges = presort.streaming_quantile_edges(chunks, n, m_num, num_bins)
        mm = np.lib.format.open_memmap(
            path, mode="w+", shape=(n, m_num),
            dtype=np.uint8 if num_bins <= 256 else np.uint16)
        lo = 0
        for block in chunks():
            c = block.shape[0]
            mm[lo:lo + c] = presort.bin_block(block, edges).T
            lo += c
        assert lo == n, f"chunk stream covered {lo} rows, expected {n}"
        mm.flush()
        del mm
        labels = np.asarray(labels)
        if num_classes is None:
            num_classes = int(labels.max()) + 1 if task == "classification" else 0
        src = cls(path, edges, labels, num_classes=max(num_classes, 2),
                  task=task, chunk_size=chunk_size)
        # sidecar written ATOMICALLY after the cache is complete — a kill
        # mid-build leaves a cache with no sidecar (never a stale one),
        # and `_cache()` verifies the pair on open (DESIGN.md §9)
        from repro.core import atomicio
        atomicio.atomic_write_json(cls.meta_path(str(path)),
                                   src._expected_meta())
        return src

    @classmethod
    def from_numpy(cls, num: np.ndarray, labels: np.ndarray, *,
                   num_bins: int, path: str,
                   chunk_size: int = 1 << 16, **kw) -> "MemmapRowSource":
        """`build` over an existing (possibly memory-mapped) (n, m_num) array."""
        n = int(num.shape[0])

        def chunks():
            for lo in range(0, n, chunk_size):
                yield np.asarray(num[lo:lo + chunk_size], np.float32)
        return cls.build(chunks, n, labels, num_bins=num_bins, path=path,
                         chunk_size=chunk_size, **kw)
