"""Tabular dataset container for DRF (paper §2.1).

The paper's datasets mix numerical and categorical columns (Leo: 3 numerical
+ 69 categorical, arities 2..10'000). We keep the two groups in separate
dense arrays; feature ids 0..m_num-1 are numerical, m_num..m-1 categorical.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TabularDataset:
    """A dataset of n rows: numerical (f32) and categorical (i32) columns."""

    num: jnp.ndarray            # (n, m_num) float32
    cat: jnp.ndarray            # (n, m_cat) int32, values in [0, arity_j)
    labels: jnp.ndarray         # (n,) int32 (classification) / float32 (regression)
    arities: tuple[int, ...]    # per categorical column
    num_classes: int = 2        # ignored for regression
    task: str = "classification"  # or "regression"

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    @property
    def m_num(self) -> int:
        return int(self.num.shape[1]) if self.num.size else 0

    @property
    def m_cat(self) -> int:
        return int(self.cat.shape[1]) if self.cat.size else 0

    @property
    def m(self) -> int:
        return self.m_num + self.m_cat

    @property
    def max_arity(self) -> int:
        return max(self.arities) if self.arities else 0

    def validate(self) -> None:
        assert self.num.ndim == 2 and self.cat.ndim == 2
        assert self.num.shape[0] == self.cat.shape[0] == self.labels.shape[0]
        assert len(self.arities) == self.m_cat
        if self.task == "classification":
            assert self.labels.dtype in (jnp.int32, jnp.int64)

    def quantize(self, num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """PLANET-style threshold buckets for `split_mode="hist"`.

        Standalone convenience (presorts internally — the fits reuse their
        own presort and call `presort.quantize` directly): buckets every
        numeric column into <= num_bins equi-depth quantile buckets.
        Returns (bin_of (m_num, n) bit-packed bucket ids —
        `presort.bin_dtype`: uint8 for <= 256 bins, uint16 past — and
        edges (m_num, num_bins) float32); both empty when there are no
        numeric columns.  Useful for feeding precomputed bucket state to
        `tree.build_tree`/`build_forest` or for inspecting the quantizer
        in tests.
        """
        from repro.core import presort
        if not self.m_num:
            return (jnp.zeros((0, self.n), presort.bin_dtype(num_bins)),
                    jnp.zeros((0, num_bins), jnp.float32))
        sorted_idx = presort.presort_columns(self.num)
        sorted_vals = presort.gather_sorted(self.num, sorted_idx)
        return presort.quantize(self.num, sorted_vals, num_bins)


def from_numpy(
    num: np.ndarray | None,
    cat: np.ndarray | None,
    labels: np.ndarray,
    arities: Sequence[int] | None = None,
    task: str = "classification",
) -> TabularDataset:
    n = labels.shape[0]
    num = np.zeros((n, 0), np.float32) if num is None else np.asarray(num, np.float32)
    cat = np.zeros((n, 0), np.int32) if cat is None else np.asarray(cat, np.int32)
    if arities is None:
        arities = tuple(int(cat[:, j].max()) + 1 if n else 2 for j in range(cat.shape[1]))
    if task == "classification":
        labels = np.asarray(labels, np.int32)
        num_classes = int(labels.max()) + 1 if n else 2
    else:
        labels = np.asarray(labels, np.float32)
        num_classes = 0
    ds = TabularDataset(
        num=jnp.asarray(num), cat=jnp.asarray(cat), labels=jnp.asarray(labels),
        arities=tuple(int(a) for a in arities), num_classes=max(num_classes, 2),
        task=task,
    )
    ds.validate()
    return ds
