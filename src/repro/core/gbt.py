"""Gradient Boosted Trees on the DRF substrate (paper §1, §2).

"While this paper mainly focuses on Random Forests, the proposed algorithm
can be applied to other DF models, notably Gradient Boosted Trees (Ye et
al., 2009).  In this case, while trees cannot be trained in parallel, the
training of each individual tree is still distributed."

Each boosting round fits a regression tree (variance impurity) to the
current pseudo-residuals with the SAME supersplit engine — the presort,
class list, seeded candidate draws and one-pass-per-level structure are all
shared (including `split_mode="hist"`, the PLANET-style approximate
baseline).  Losses: squared error (regression) and logistic (binary
classification).

Inference stacks the fitted rounds into a `forest.PackedForest`:
`predict_raw` is ONE jitted device call (vmap-over-rounds descent + the
scaled sum + base score fused), not a host-side tree loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forest as forest_lib
from repro.core import presort, tree as tree_lib
from repro.core.dataset import TabularDataset


@dataclasses.dataclass
class GBTParams:
    num_rounds: int = 20
    learning_rate: float = 0.1
    max_depth: int = 4
    min_records: float = 1.0
    num_candidates: int | None = None   # None = all features (GBT default)
    loss: str = "squared"               # squared | logistic
    backend: str = "segment"
    split_mode: str = "exact"           # exact | hist (PLANET baseline)
    num_bins: int = 255                 # hist-mode bucket budget per column
    seed: int = 0


# trace counter: tests assert predict_raw compiles ONCE for a whole model
# (no per-round retraces) — mirrors forest._PREDICT_TRACES
_RAW_TRACES = [0]


@functools.partial(jax.jit, static_argnames=("m_num", "iters"))
def _gbt_predict_raw_jit(feature, threshold, is_cat, cat_mask, children,
                         value, num, cat, base_score, learning_rate,
                         m_num, iters):
    """base + lr · Σ_rounds tree_t(x), one device program for all rounds.

    Reuses the stacked-forest descent (forest._forest_predict_impl, a vmap
    over the round axis of the packed arrays); the scaled reduction over
    rounds stays inside the same jit.
    """
    _RAW_TRACES[0] += 1
    preds = forest_lib._forest_predict_impl(
        feature, threshold, is_cat, cat_mask, children, value, num, cat,
        m_num, iters, reduce_mean=False)                     # (T, B, 1)
    return base_score + learning_rate * preds[:, :, 0].sum(axis=0)


@dataclasses.dataclass
class GBTModel:
    """Gradient Boosted Trees on the DRF tree builder (paper §1).

    Each boosting round fits one regression tree (variance impurity,
    `bagging="none"`, all features candidates by default) to the current
    pseudo-residuals with the same fused one-program-per-level builder as
    `RandomForest` — rounds are sequential (tree t+1 needs tree t's
    predictions), so GBT uses the per-tree builder, not the multi-tree
    batch.  `split_mode="hist"` quantizes numeric columns once before the
    first round and every round scores bucket boundaries only (the
    PLANET-style baseline; exact is the default).  Losses: `"squared"`
    (regression; `predict` returns the raw score) and `"logistic"` (binary
    classification; `predict` thresholds at 0, `predict_proba` returns
    (B, 2) probabilities).

    `fit(ds)` expects a `TabularDataset`; for `"logistic"` the labels must
    be 0/1 ints.  `base_score` is the fitted prior (mean / log-odds) that
    every prediction starts from.  Inputs to `predict*` are (B, m_num)
    numeric and (B, m_cat) categorical arrays, as for `RandomForest`.
    Fitted rounds are packed into a `forest.PackedForest` so `predict_raw`
    is ONE jitted device call regardless of the round count.
    """

    params: GBTParams
    trees: list = dataclasses.field(default_factory=list)
    base_score: float = 0.0
    m: int = 0
    packed: Optional[forest_lib.PackedForest] = None

    def fit(self, ds: TabularDataset, engine=None,
            cat_engine=None) -> "GBTModel":
        """Fit the boosted rounds; `engine`/`cat_engine` optionally select
        `repro.core.level` SplitEngines (e.g. the mesh-sharded ones) — each
        round's tree runs through the same LevelPlan as RandomForest."""
        p = self.params
        self.m = ds.m
        y = np.asarray(ds.labels, np.float64)
        if p.loss == "logistic":
            pbar = np.clip(y.mean(), 1e-6, 1 - 1e-6)
            self.base_score = float(np.log(pbar / (1 - pbar)))
        else:
            self.base_score = float(y.mean())
        f = np.full_like(y, self.base_score, dtype=np.float64)

        if ds.m_num:
            sorted_idx = presort.presort_columns(ds.num)
            sorted_vals = presort.gather_sorted(ds.num, sorted_idx)
        else:
            sorted_idx = jnp.zeros((0, ds.n), jnp.int32)
            sorted_vals = jnp.zeros((0, ds.n), jnp.float32)

        tparams = tree_lib.TreeParams(
            max_depth=p.max_depth, min_records=p.min_records,
            num_candidates=p.num_candidates or ds.m, impurity="variance",
            task="regression", backend=p.backend, bagging="none",
            split_mode=p.split_mode, num_bins=p.num_bins)
        # hist mode: quantize once, before the first round — the bucket
        # state depends only on the columns, not on the residuals
        bin_of = bin_edges = None
        if p.split_mode == "hist" and ds.m_num:
            bin_of, bin_edges = presort.quantize(ds.num, sorted_vals,
                                                 p.num_bins)

        for t in range(p.num_rounds):
            if p.loss == "logistic":
                prob = 1.0 / (1.0 + np.exp(-f))
                resid = y - prob                       # negative gradient
            else:
                resid = y - f
            tr, _ = tree_lib.build_tree(
                num=ds.num, cat=ds.cat,
                labels=jnp.asarray(resid, jnp.float32),
                sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                arities=ds.arities, num_classes=2,
                params=tparams, seed=p.seed, tree_idx=t,
                bin_of=bin_of, bin_edges=bin_edges,
                engine=engine, cat_engine=cat_engine)
            self.trees.append(tr)
            step = np.asarray(tr.predict_raw(ds.num, ds.cat))[:, 0]
            f = f + p.learning_rate * step
        if self.trees:                        # num_rounds=0: prior only
            self.packed = forest_lib.pack_trees(self.trees)
        return self

    def _packed(self) -> forest_lib.PackedForest:
        assert self.trees, "fit first"
        if self.packed is None or self.packed.num_trees != len(self.trees):
            self.packed = forest_lib.pack_trees(self.trees)
        return self.packed

    def predict_raw(self, num, cat) -> np.ndarray:
        """Raw boosted score, (B,) — ONE jitted call for all rounds."""
        if not self.trees:                    # num_rounds=0: the prior
            B = (np.asarray(num).shape[0] if np.asarray(num).size
                 else np.asarray(cat).shape[0])
            return np.full((B,), self.base_score, np.float32)
        pk = self._packed()
        return np.asarray(_gbt_predict_raw_jit(
            pk.feature, pk.threshold, pk.is_cat, pk.cat_mask, pk.children,
            pk.value, jnp.asarray(num, jnp.float32),
            jnp.asarray(cat, jnp.int32), jnp.float32(self.base_score),
            jnp.float32(self.params.learning_rate), pk.m_num, pk.iters))

    def predict(self, num, cat) -> np.ndarray:
        f = self.predict_raw(num, cat)
        if self.params.loss == "logistic":
            return (f > 0).astype(np.int32)
        return f

    def predict_proba(self, num, cat) -> np.ndarray:
        assert self.params.loss == "logistic"
        p1 = 1.0 / (1.0 + np.exp(-self.predict_raw(num, cat).astype(np.float64)))
        return np.stack([1 - p1, p1], -1)
