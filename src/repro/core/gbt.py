"""Gradient Boosted Trees on the DRF substrate (paper §1, §2).

"While this paper mainly focuses on Random Forests, the proposed algorithm
can be applied to other DF models, notably Gradient Boosted Trees (Ye et
al., 2009).  In this case, while trees cannot be trained in parallel, the
training of each individual tree is still distributed."

Each boosting round fits a regression tree (variance impurity) to the
current pseudo-residuals with the SAME supersplit engine — the presort,
class list, seeded candidate draws and one-pass-per-level structure are all
shared.  Losses: squared error (regression) and logistic (binary
classification).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import presort, tree as tree_lib
from repro.core.dataset import TabularDataset


@dataclasses.dataclass
class GBTParams:
    num_rounds: int = 20
    learning_rate: float = 0.1
    max_depth: int = 4
    min_records: float = 1.0
    num_candidates: int | None = None   # None = all features (GBT default)
    loss: str = "squared"               # squared | logistic
    backend: str = "segment"
    seed: int = 0


@dataclasses.dataclass
class GBTModel:
    """Gradient Boosted Trees on the DRF tree builder (paper §1).

    Each boosting round fits one regression tree (variance impurity,
    `bagging="none"`, all features candidates by default) to the current
    pseudo-residuals with the same fused one-program-per-level builder as
    `RandomForest` — rounds are sequential (tree t+1 needs tree t's
    predictions), so GBT uses the per-tree builder, not the multi-tree
    batch.  Losses: `"squared"` (regression; `predict` returns the raw
    score) and `"logistic"` (binary classification; `predict` thresholds
    at 0, `predict_proba` returns (B, 2) probabilities).

    `fit(ds)` expects a `TabularDataset`; for `"logistic"` the labels must
    be 0/1 ints.  `base_score` is the fitted prior (mean / log-odds) that
    every prediction starts from.  Inputs to `predict*` are (B, m_num)
    numeric and (B, m_cat) categorical arrays, as for `RandomForest`.
    """

    params: GBTParams
    trees: list = dataclasses.field(default_factory=list)
    base_score: float = 0.0
    m: int = 0

    def fit(self, ds: TabularDataset) -> "GBTModel":
        p = self.params
        self.m = ds.m
        y = np.asarray(ds.labels, np.float64)
        if p.loss == "logistic":
            pbar = np.clip(y.mean(), 1e-6, 1 - 1e-6)
            self.base_score = float(np.log(pbar / (1 - pbar)))
        else:
            self.base_score = float(y.mean())
        f = np.full_like(y, self.base_score, dtype=np.float64)

        if ds.m_num:
            sorted_idx = presort.presort_columns(ds.num)
            sorted_vals = presort.gather_sorted(ds.num, sorted_idx)
        else:
            sorted_idx = jnp.zeros((0, ds.n), jnp.int32)
            sorted_vals = jnp.zeros((0, ds.n), jnp.float32)

        tparams = tree_lib.TreeParams(
            max_depth=p.max_depth, min_records=p.min_records,
            num_candidates=p.num_candidates or ds.m, impurity="variance",
            task="regression", backend=p.backend, bagging="none")

        for t in range(p.num_rounds):
            if p.loss == "logistic":
                prob = 1.0 / (1.0 + np.exp(-f))
                resid = y - prob                       # negative gradient
            else:
                resid = y - f
            tr, _ = tree_lib.build_tree(
                num=ds.num, cat=ds.cat,
                labels=jnp.asarray(resid, jnp.float32),
                sorted_vals=sorted_vals, sorted_idx=sorted_idx,
                arities=ds.arities, num_classes=2,
                params=tparams, seed=p.seed, tree_idx=t)
            self.trees.append(tr)
            step = np.asarray(tr.predict_raw(ds.num, ds.cat))[:, 0]
            f = f + p.learning_rate * step
        return self

    def predict_raw(self, num, cat) -> np.ndarray:
        f = np.full((np.asarray(num).shape[0] if np.asarray(num).size
                     else np.asarray(cat).shape[0],), self.base_score)
        for tr in self.trees:
            f = f + self.params.learning_rate * np.asarray(
                tr.predict_raw(jnp.asarray(num, jnp.float32),
                               jnp.asarray(cat, jnp.int32)))[:, 0]
        return f

    def predict(self, num, cat) -> np.ndarray:
        f = self.predict_raw(num, cat)
        if self.params.loss == "logistic":
            return (f > 0).astype(np.int32)
        return f

    def predict_proba(self, num, cat) -> np.ndarray:
        assert self.params.loss == "logistic"
        p1 = 1.0 / (1.0 + np.exp(-self.predict_raw(num, cat)))
        return np.stack([1 - p1, p1], -1)
