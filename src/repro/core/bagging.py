"""Deterministic seeded bagging (paper §2.2).

"Instead of sending indices over the network, DRF uses a deterministic
pseudorandom generator so that all workers agree on the set of bagged
examples without network communication."

We realize this with JAX's counter-based threefry PRNG: every device derives
the identical per-sample bag count from (forest_seed, tree_index) — zero
bytes on the wire, exactly the paper's property.

Two modes:
  * "poisson"     — independent Poisson(1) counts per sample (the standard
                    distributed bootstrap; O(1/n) from multinomial, scales to
                    row-sharded data with no communication).  Default.
  * "multinomial" — exact n-out-of-n sampling with replacement (the paper's
                    stated scheme); requires materializing n draws on one
                    host, used in tests and small runs.
  * "none"        — no bagging (weight 1 everywhere), for GBT.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n", "mode"))
def bag_counts(seed: jnp.ndarray, tree_idx, n: int, mode: str = "poisson") -> jnp.ndarray:
    """Per-sample bag multiplicity for one tree. Returns (n,) float32."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed) if jnp.ndim(seed) == 0 else seed,
                             tree_idx)
    if mode == "poisson":
        return jax.random.poisson(key, 1.0, (n,)).astype(jnp.float32)
    if mode == "multinomial":
        draws = jax.random.randint(key, (n,), 0, n)
        return jnp.zeros((n,), jnp.float32).at[draws].add(1.0)
    if mode == "none":
        return jnp.ones((n,), jnp.float32)
    raise ValueError(f"unknown bagging mode {mode!r}")


@functools.partial(jax.jit, static_argnames=("n", "mode"))
def bag_counts_forest(seed, tree_indices: jnp.ndarray, n: int,
                      mode: str = "poisson") -> jnp.ndarray:
    """`bag_counts` for a batch of trees at once. Returns (T, n) float32.

    Bit-identical per tree to calling `bag_counts(seed, t, n, mode)` — the
    fold-in chain is elementwise, so the batched draw of tree t equals the
    per-tree draw (asserted by tests/test_forest_batch.py).  Used by
    `tree.build_forest` to stack the per-tree bootstrap row weights.
    """
    return jax.vmap(lambda t: bag_counts(seed, t, n, mode))(tree_indices)


@functools.partial(jax.jit, static_argnames=("num_leaves", "m", "m_prime", "usb"))
def candidate_features(
    key: jnp.ndarray, depth, num_leaves: int, m: int, m_prime: int, usb: bool = False
) -> jnp.ndarray:
    """Per-leaf candidate feature mask (paper §2.4 attribute sampling; §3.2 USB).

    Returns (num_leaves, m) bool — True where feature j is a candidate for
    leaf h.  With `usb=True` (Unique Set of Bagged features per depth, z=1)
    one draw is shared by every leaf of the depth, the variant the paper's
    complexity analysis §3.2 shows is critical for distributed cost.

    The draw is PADDING-INDEPENDENT: each leaf row folds its own index into
    the (key, depth) key and draws (m,) uniforms, so row h of the returned
    mask depends only on (key, depth, h, m, m_prime) — never on
    `num_leaves`.  The tree builders pad the open-leaf count (per tree, or
    to the batch maximum in `tree.build_forest`), and this property is what
    keeps padded and differently-padded builds bit-identical.
    """
    key = jax.random.fold_in(key, depth)
    z = 1 if usb else num_leaves
    # Draw m' features without replacement per subset via uniform top-k.
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, jnp.arange(z))
    g = jax.vmap(lambda k: jax.random.uniform(k, (m,)))(keys)
    _, idx = jax.lax.top_k(g, m_prime)
    mask = jnp.zeros((z, m), bool).at[jnp.arange(z)[:, None], idx].set(True)
    if usb:
        mask = jnp.broadcast_to(mask, (num_leaves, m))
    return mask
