"""Deterministic seeded bagging (paper §2.2).

"Instead of sending indices over the network, DRF uses a deterministic
pseudorandom generator so that all workers agree on the set of bagged
examples without network communication."

We realize this with JAX's counter-based threefry PRNG: every device derives
the identical per-sample bag count from (forest_seed, tree_index) — zero
bytes on the wire, exactly the paper's property.

Two modes:
  * "poisson"     — independent Poisson(1) counts per sample (the standard
                    distributed bootstrap; O(1/n) from multinomial, scales to
                    row-sharded data with no communication).  Default.
  * "multinomial" — exact n-out-of-n sampling with replacement (the paper's
                    stated scheme); requires materializing n draws on one
                    host, used in tests and small runs.
  * "none"        — no bagging (weight 1 everywhere), for GBT.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n", "mode"))
def bag_counts(seed: jnp.ndarray, tree_idx, n: int, mode: str = "poisson") -> jnp.ndarray:
    """Per-sample bag multiplicity for one tree. Returns (n,) float32."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed) if jnp.ndim(seed) == 0 else seed,
                             tree_idx)
    if mode == "poisson":
        return jax.random.poisson(key, 1.0, (n,)).astype(jnp.float32)
    if mode == "multinomial":
        draws = jax.random.randint(key, (n,), 0, n)
        return jnp.zeros((n,), jnp.float32).at[draws].add(1.0)
    if mode == "none":
        return jnp.ones((n,), jnp.float32)
    raise ValueError(f"unknown bagging mode {mode!r}")


@functools.partial(jax.jit, static_argnames=("num_leaves", "m", "m_prime", "usb"))
def candidate_features(
    key: jnp.ndarray, depth, num_leaves: int, m: int, m_prime: int, usb: bool = False
) -> jnp.ndarray:
    """Per-leaf candidate feature mask (paper §2.4 attribute sampling; §3.2 USB).

    Returns (num_leaves, m) bool — True where feature j is a candidate for
    leaf h.  With `usb=True` (Unique Set of Bagged features per depth, z=1)
    one draw is shared by every leaf of the depth, the variant the paper's
    complexity analysis §3.2 shows is critical for distributed cost.
    """
    key = jax.random.fold_in(key, depth)
    z = 1 if usb else num_leaves
    # Draw m' features without replacement per subset via uniform top-k.
    g = jax.random.uniform(key, (z, m))
    _, idx = jax.lax.top_k(g, m_prime)
    mask = jnp.zeros((z, m), bool).at[jnp.arange(z)[:, None], idx].set(True)
    if usb:
        mask = jnp.broadcast_to(mask, (num_leaves, m))
    return mask
