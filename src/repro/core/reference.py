"""The reference (pre-fusion) tree builder — executable specification.

Kept as the executable specification of Alg. 2: one jitted call per level
piece with numpy round-trips between them, exactly the seed
implementation.  The fused `tree.build_tree` (and the batched
`tree.build_forest`) must reproduce its trees bit-for-bit
(tests/test_fused_level.py, tests/test_forest_batch.py), and
benchmarks/level_step_bench.py measures the fused speedup against it.
EXACT mode only: the histogram mode is an approximation with no
midpoint-exhaustive specification to match (its tests compare the batched
builder against the per-tree fused builder instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bagging, class_list, splits
from repro.core.level.engines import (_categorical_supersplits,
                                      _numeric_supersplits)
from repro.core.level.plan import _leaf_totals, _pad_leaves
from repro.core.tree import (LevelStats, Tree, _assemble_tree, _NodeAccum,
                             _tree_setup)


def _eval_conditions_core(num, cat, leaf_of, feat_of_leaf, thr_of_leaf,
                          iscat_of_leaf, mask_of_leaf, m_num):
    from repro.core.level.plan import _eval_conditions_core as impl
    return impl(num, cat, leaf_of, feat_of_leaf, thr_of_leaf, iscat_of_leaf,
                mask_of_leaf, m_num)


_evaluate_conditions = functools.partial(jax.jit, static_argnames=("m_num",))(
    _eval_conditions_core)


@jax.jit
def _reassign(leaf_of, bits, new_left, new_right):
    """Alg. 2 step 6: map samples to child leaf ids (0 if child closed)."""
    child = jnp.where(bits, new_left[leaf_of], new_right[leaf_of])
    return jnp.where(leaf_of > 0, child, 0)


def build_tree_reference(
    *,
    num: jnp.ndarray, cat: jnp.ndarray, labels: jnp.ndarray,
    sorted_vals: jnp.ndarray, sorted_idx: jnp.ndarray,
    arities: tuple[int, ...], num_classes: int,
    params, seed: int, tree_idx: int,
    collect_stats: bool = False,
    supersplit_fn=None,
) -> tuple[Tree, list[LevelStats]]:
    """The seed builder: one jitted call per level piece, numpy in between."""
    assert params.split_mode == "exact", \
        "build_tree_reference is the exact-mode specification"
    n, m_num, m_cat, m, max_arity, m_prime = _tree_setup(
        sorted_vals, arities, labels, params)
    task = params.task

    w = bagging.bag_counts(seed, tree_idx, n, params.bagging)
    stats = splits.row_stats(labels, w, num_classes, task)
    cnt = splits.count_fn(task)
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)

    acc = _NodeAccum(num_classes, task)
    root = acc.new_node(0)
    open_nodes = [root]                       # leaf id h (1-based) -> node id
    leaf_of = jnp.ones((n,), jnp.int32)       # all samples at the root
    stats_log: list[LevelStats] = []

    for depth in range(params.max_depth + 1):
        L = len(open_nodes)
        if L == 0:
            break
        Lp = _pad_leaves(L, params.leaf_pad)

        # leaf totals -> node values & forced closes
        totals = np.asarray(_leaf_totals(leaf_of, stats, w, Lp))  # (Lp+1, S)
        counts = np.asarray(cnt(jnp.asarray(totals)))
        for h, node in enumerate(open_nodes, start=1):
            acc.set_value(node, totals[h], counts[h], task)

        at_max_depth = depth >= params.max_depth
        splittable = np.array(
            [counts[h] >= 2 * params.min_records and not at_max_depth
             for h in range(1, L + 1)] + [False] * (Lp - L))
        if not splittable.any():
            break

        # Alg. 2 step 3: query the splitters for the optimal supersplit
        cand = bagging.candidate_features(fkey, depth, Lp, m, m_prime, params.usb)
        cand = cand & jnp.asarray(splittable)[:, None]
        cand_p = jnp.concatenate([jnp.zeros((1, m), bool), cand], 0)  # leaf 0 = closed

        all_gains = np.full((m, Lp + 1), -np.inf, np.float32)
        all_thr = np.zeros((m, Lp + 1), np.float32)
        all_masks = None
        if m_num:
            if supersplit_fn is not None:
                g, t = supersplit_fn(
                    sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            elif params.backend == "kernel":
                from repro.kernels import ops as kops
                g, t = kops.split_scan_supersplit(
                    sorted_vals, sorted_idx, leaf_of, w, labels,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records, num_classes=num_classes)
            else:
                g, t = _numeric_supersplits(
                    params.backend, sorted_vals, sorted_idx, leaf_of, w, stats,
                    cand_p[:, :m_num].T, Lp, params.impurity, task,
                    params.min_records)
            all_gains[:m_num], all_thr[:m_num] = np.asarray(g), np.asarray(t)
        if m_cat:
            g, masks = _categorical_supersplits(
                cat.T, leaf_of, w, stats, cand_p[:, m_num:].T, Lp, max_arity,
                params.impurity, task, params.min_records)
            all_gains[m_num:] = np.asarray(g)
            all_masks = np.asarray(masks)                    # (m_cat, Lp+1, V)

        # tree builder merges partial supersplits (Alg. 2 step 3, final argmax)
        best_feat = all_gains.argmax(axis=0)                 # (Lp+1,)
        best_gain = all_gains[best_feat, np.arange(Lp + 1)]

        # Alg. 2 step 8: close leaves with no good condition
        feat_of_leaf = np.zeros(Lp + 1, np.int32)
        thr_of_leaf = np.zeros(Lp + 1, np.float32)
        iscat_of_leaf = np.zeros(Lp + 1, bool)
        mask_of_leaf = np.zeros((Lp + 1, max_arity), bool)
        new_left = np.zeros(Lp + 1, np.int32)
        new_right = np.zeros(Lp + 1, np.int32)
        next_open: list[int] = []
        any_split = False
        for h in range(1, L + 1):
            node = open_nodes[h - 1]
            if not splittable[h - 1] or not np.isfinite(best_gain[h]) or best_gain[h] <= 1e-9:
                continue
            j = int(best_feat[h])
            any_split = True
            acc.feature[node] = j
            acc.gain[node] = float(best_gain[h])
            feat_of_leaf[h] = j
            if j < m_num:
                acc.threshold[node] = float(all_thr[j, h])
                thr_of_leaf[h] = all_thr[j, h]
            else:
                acc.is_cat[node] = True
                iscat_of_leaf[h] = True
                cm = all_masks[j - m_num, h]
                acc.cat_mask[node] = cm.copy()
                mask_of_leaf[h] = cm
            lc, rc = acc.new_node(depth + 1), acc.new_node(depth + 1)
            acc.children[node] = [lc, rc]
            next_open.extend([lc, rc])
            new_left[h] = len(next_open) - 1               # 1-based ids below
            new_right[h] = len(next_open)

        if collect_stats:
            open_w = float(counts[1:L + 1].sum())
            stats_log.append(LevelStats(
                depth=depth, open_leaves=L,
                network_bits_bitmap=int(open_w),
                network_bits_supersplit=int(m * (Lp + 1) * 64),
                class_list_bits=class_list.storage_bits(n, L),
                feature_passes=int(min(m_prime * (1 if params.usb else L), m)),
                rows_scanned=n * min(m_prime * (1 if params.usb else L), m)))

        if not any_split:
            break

        # Alg. 2 steps 5-7: evaluate conditions (1 bit/sample) and reassign
        bits = _evaluate_conditions(
            num, cat, leaf_of, jnp.asarray(feat_of_leaf), jnp.asarray(thr_of_leaf),
            jnp.asarray(iscat_of_leaf), jnp.asarray(mask_of_leaf), m_num)
        leaf_of = _reassign(leaf_of, bits, jnp.asarray(new_left), jnp.asarray(new_right))
        open_nodes = next_open

        # Sprint-style pruning switch (paper §3): compact rows in closed
        # leaves once they dominate.  The presorted order is FILTERED, not
        # re-sorted (stability preserves it), so the one-time cost is one
        # pass — the trade-off rule the paper describes.
        if params.prune_closed_frac < 1.0 and n > 0:
            lf_np = np.asarray(leaf_of)
            keep = lf_np > 0
            frac_closed = 1.0 - keep.mean()
            if frac_closed >= params.prune_closed_frac and keep.any() \
                    and keep.sum() < n:
                remap = np.cumsum(keep) - 1
                idx_np = np.asarray(sorted_idx)
                vals_np = np.asarray(sorted_vals)
                kept_cols = keep[idx_np]                      # (m_num, n)
                n_new = int(keep.sum())
                new_idx = np.empty((m_num, n_new), np.int32)
                new_vals = np.empty((m_num, n_new), np.float32)
                for j in range(m_num):
                    sel = kept_cols[j]
                    new_idx[j] = remap[idx_np[j][sel]]
                    new_vals[j] = vals_np[j][sel]
                sorted_idx = jnp.asarray(new_idx)
                sorted_vals = jnp.asarray(new_vals)
                num = num[jnp.asarray(keep)] if num.size else num
                cat = cat[jnp.asarray(keep)] if cat.size else cat
                stats = stats[jnp.asarray(keep)]
                w = w[jnp.asarray(keep)]
                labels = labels[jnp.asarray(keep)]
                leaf_of = jnp.asarray(lf_np[keep])
                n = n_new

    return _assemble_tree(acc, max_arity, m_num, task), stats_log
