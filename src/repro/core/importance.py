"""Distributed feature importance (paper goal (5), §1).

Mean-decrease-in-impurity is additive over (tree, node) pairs: each splitter
can accumulate the gains of the splits on ITS columns locally and a single
tiny allreduce merges the per-feature partial sums — exactly how the paper
distributes it.  `mdi_partial` below is the per-splitter computation (gains
restricted to an owned column range); `mdi_importance` is the merged total
(the allreduce is a sum of m floats — negligible, as the paper notes).
"""
from __future__ import annotations

import numpy as np


def mdi_importance(trees, m: int) -> np.ndarray:
    """Mean decrease in impurity, normalized to sum 1."""
    imp = np.zeros(m, np.float64)
    for tr in trees:
        sel = tr.feature >= 0
        np.add.at(imp, tr.feature[sel], tr.gain[sel])
    tot = imp.sum()
    return (imp / tot if tot > 0 else imp).astype(np.float32)


def mdi_partial(trees, m: int, lo: int, hi: int) -> np.ndarray:
    """Per-splitter partial MDI: gains of splits on columns [lo, hi) only.

    sum over splitters of mdi_partial == unnormalized mdi_importance —
    the paper's distributed feature-importance decomposition."""
    imp = np.zeros(m, np.float64)
    for tr in trees:
        sel = (tr.feature >= lo) & (tr.feature < hi)
        np.add.at(imp, tr.feature[sel], tr.gain[sel])
    return imp


def permutation_importance(forest, ds, metric: str = "accuracy",
                           seed: int = 0, max_rows: int = 4096) -> np.ndarray:
    """Permutation importance on a (sub)sample — the model-agnostic check."""
    rng = np.random.default_rng(seed)
    n = min(ds.n, max_rows)
    idx = rng.permutation(ds.n)[:n]
    num = np.asarray(ds.num)[idx]
    cat = np.asarray(ds.cat)[idx]
    y = np.asarray(ds.labels)[idx]

    def score(numx, catx):
        pred = np.asarray(forest.predict(numx, catx))
        return float((pred == y).mean())

    base = score(num, cat)
    out = np.zeros(ds.m, np.float32)
    for j in range(ds.m):
        perm = rng.permutation(n)
        if j < ds.m_num:
            numx = num.copy(); numx[:, j] = numx[perm, j]
            out[j] = base - score(numx, cat)
        else:
            catx = cat.copy(); jj = j - ds.m_num
            catx[:, jj] = catx[perm, jj]
            out[j] = base - score(num, catx)
    return out
