"""Supersplit search (paper §2.4, Alg. 1).

A *supersplit* is the set of best splits for every open leaf at the current
depth, computed in ONE pass per candidate feature over the presorted data.

Unified statistics
------------------
Split scoring works on per-leaf "stats" accumulators so the same engines
serve Random Forests (classification) and Gradient Boosted Trees
(regression, paper §1 "can be applied to other DF models, notably GBT"):

  * classification: stats[k] = bag_weight * one_hot(label, C)        (S = C)
  * regression:     stats[k] = bag_weight * [1, y, y^2]              (S = 3)

`weighted_impurity(H)` returns N·impurity so that
gain = imp(parent) − imp(left) − imp(right) is additive.

Two exact numerical backends (identical results, different machines):

  * `scan`    — the faithful Alg. 1: a sequential pass carrying one histogram
                per open leaf (H ∈ (ℓ+1, S)) plus the last-seen value v_h.
                This is the reference semantics and the shape the Pallas
                kernel (`repro.kernels.split_scan`) implements on TPU.
  * `segment` — beyond-paper TPU-native backend: a stable counting-sort of
                the presorted order by leaf id makes every leaf contiguous;
                per-leaf cumulative histograms then become segmented cumsums
                — fully parallel across rows (no sequential carry), which is
                what the VPU wants.  Bitwise-equal split choices up to
                floating-point summation order.

Leaf id convention: 0 = closed (sentinel, paper §2.3), open leaves 1..ℓ.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

NEG = jnp.float32(-jnp.inf)

# jax<0.5 compat: `optimization_barrier` ships without a vmap batching rule.
# The barrier is an identity per operand (it only pins values against XLA
# re-fusion), so batching is a pass-through.  Registered here so the
# leaf-ordered supersplit below — which pins gain/tau before its
# associative scan — also lowers under the tree-axis vmap of
# `tree.build_forest` (DESIGN.md §3).
try:  # pragma: no cover - newer jax moves these private paths (and ships
    # the rule built in, making the shim unnecessary); anything else that
    # goes wrong here should surface, not turn into an opaque vmap error
    from jax._src.interpreters import batching as _batching
    from jax._src.lax.lax import optimization_barrier_p as _opt_barrier_p

    if _opt_barrier_p not in _batching.primitive_batchers:
        def _opt_barrier_batcher(args, dims, **params):
            return _opt_barrier_p.bind(*args, **params), dims
        _batching.primitive_batchers[_opt_barrier_p] = _opt_barrier_batcher
except (ImportError, AttributeError):
    pass


# ---------------------------------------------------------------------------
# Stats & impurities
# ---------------------------------------------------------------------------

def row_stats(labels: jnp.ndarray, weights: jnp.ndarray, num_classes: int,
              task: str) -> jnp.ndarray:
    """Per-row stats contributions, (n, S)."""
    if task == "classification":
        return jax.nn.one_hot(labels, num_classes, dtype=jnp.float32) * weights[:, None]
    y = labels.astype(jnp.float32)
    return jnp.stack([weights, weights * y, weights * y * y], axis=-1)


def count_fn(task: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if task == "classification":
        return lambda h: h.sum(-1)
    return lambda h: h[..., 0]


def weighted_impurity(h: jnp.ndarray, impurity: str) -> jnp.ndarray:
    """N * impurity for a stats accumulator h (..., S). Safe at N=0."""
    if impurity == "gini":
        n = h.sum(-1)
        return n - jnp.where(n > 0, (h * h).sum(-1) / jnp.maximum(n, 1e-12), 0.0)
    if impurity == "entropy":
        n = h.sum(-1, keepdims=True)
        p = h / jnp.maximum(n, 1e-12)
        plogp = jnp.where(h > 0, p * jnp.log(jnp.maximum(p, 1e-12)), 0.0)
        return -(n[..., 0] * plogp.sum(-1))
    if impurity == "variance":
        w, wy, wy2 = h[..., 0], h[..., 1], h[..., 2]
        return jnp.maximum(wy2 - jnp.where(w > 0, wy * wy / jnp.maximum(w, 1e-12), 0.0), 0.0)
    raise ValueError(f"unknown impurity {impurity!r}")


def split_gain(left: jnp.ndarray, right: jnp.ndarray, impurity: str) -> jnp.ndarray:
    parent = left + right
    return (weighted_impurity(parent, impurity)
            - weighted_impurity(left, impurity)
            - weighted_impurity(right, impurity))


# ---------------------------------------------------------------------------
# Numerical — faithful Alg. 1 scan backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_leaves", "impurity", "task"))
def best_numeric_split_scan(
    vals_sorted: jnp.ndarray,    # (n,) float32, ascending
    leaf_sorted: jnp.ndarray,    # (n,) int32 in [0, L], 0 = closed
    w_sorted: jnp.ndarray,       # (n,) float32 bag weights
    stats_sorted: jnp.ndarray,   # (n, S) float32 row stats
    cand_leaf: jnp.ndarray,      # (L+1,) bool — feature is candidate for leaf
    num_leaves: int,             # L (static)
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
    h_init: jnp.ndarray | None = None,   # (L+1, S) prefix from earlier row shards
    v_init: jnp.ndarray | None = None,   # (L+1,)  last in-bag value in earlier shards
    totals: jnp.ndarray | None = None,   # (L+1, S) GLOBAL per-leaf totals
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 1 verbatim: one streaming pass, H ∈ (L+1, S) carried.

    The optional h_init/v_init/totals let a row shard resume the scan exactly
    where the previous (presorted-order) shard left off — the 2-D sharding
    extension (DESIGN.md §5).  Returns (best_gain, best_threshold), each
    (L+1,); entry 0 (closed) unused.
    """
    L1, s_dim = num_leaves + 1, stats_sorted.shape[-1]
    if totals is None:
        totals = jax.ops.segment_sum(
            jnp.where((w_sorted > 0)[:, None], stats_sorted, 0.0),
            leaf_sorted, num_segments=L1)
    cnt = count_fn(task)

    def step(carry, xs):
        H, v, best_s, best_t = carry
        a, h, w, srow = xs
        active = (h > 0) & cand_leaf[h] & (w > 0)
        Hh, vh = H[h], v[h]
        tau = (a + vh) * 0.5
        left, right = Hh, totals[h] - Hh
        ok = active & (a > vh) & jnp.isfinite(vh) \
            & (cnt(left) >= min_records) & (cnt(right) >= min_records)
        g = jnp.where(ok, split_gain(left, right, impurity), NEG)
        better = g > best_s[h]
        best_s = best_s.at[h].set(jnp.where(better, g, best_s[h]))
        best_t = best_t.at[h].set(jnp.where(better, tau, best_t[h]))
        H = H.at[h].add(jnp.where(active, srow, 0.0))
        v = v.at[h].set(jnp.where(active, a, vh))
        return (H, v, best_s, best_t), None

    init = (jnp.zeros((L1, s_dim), jnp.float32) if h_init is None else h_init,
            jnp.full((L1,), jnp.inf, jnp.float32) if v_init is None else v_init,
            jnp.full((L1,), NEG), jnp.zeros((L1,), jnp.float32))
    # v init=+inf makes (a > v) False for the first in-bag row of each leaf,
    # after which v tracks the last in-bag value — the paper's v_h.
    (H, v, best_s, best_t), _ = jax.lax.scan(
        step, init, (vals_sorted, leaf_sorted, w_sorted, stats_sorted))
    del H, v
    return best_s, best_t


# ---------------------------------------------------------------------------
# Numerical — sorted-segment backend (TPU-native, exact)
# ---------------------------------------------------------------------------

def _segmented_cummax_exclusive(x: jnp.ndarray, is_start: jnp.ndarray) -> jnp.ndarray:
    """Exclusive running max within segments (reset at is_start)."""
    def combine(a, b):
        (va, ba), (vb, bb) = a, b
        return jnp.where(bb, vb, jnp.maximum(va, vb)), ba | bb
    inc, _ = jax.lax.associative_scan(combine, (x, is_start))
    exc = jnp.concatenate([NEG[None], inc[:-1]])
    return jnp.where(is_start, NEG, exc)


def _segmented_cummax_exclusive_2d(x: jnp.ndarray,
                                   is_start: jnp.ndarray) -> jnp.ndarray:
    """`_segmented_cummax_exclusive` batched along axis 0 (scan on axis 1)."""
    m = x.shape[0]
    def combine(a, b):
        (va, ba), (vb, bb) = a, b
        return jnp.where(bb, vb, jnp.maximum(va, vb)), ba | bb
    inc, _ = jax.lax.associative_scan(combine, (x, is_start), axis=1)
    exc = jnp.concatenate([jnp.full((m, 1), NEG), inc[:, :-1]], axis=1)
    return jnp.where(is_start, NEG, exc)


@functools.partial(jax.jit, static_argnames=("num_leaves", "impurity", "task"))
def best_numeric_split_segment(
    vals_sorted: jnp.ndarray,
    leaf_sorted: jnp.ndarray,
    w_sorted: jnp.ndarray,
    stats_sorted: jnp.ndarray,
    cand_leaf: jnp.ndarray,
    num_leaves: int,
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
    h_init: jnp.ndarray | None = None,
    v_init: jnp.ndarray | None = None,
    totals: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact vectorized supersplit: counting-sort by leaf + segmented cumsum."""
    L1 = num_leaves + 1
    n = vals_sorted.shape[0]
    cnt = count_fn(task)

    order = jnp.argsort(leaf_sorted, stable=True)          # leaves contiguous,
    lf = leaf_sorted[order]                                 # value-sorted inside
    a = vals_sorted[order]
    w = w_sorted[order]
    inbag = (w > 0) & (lf > 0)
    contrib = jnp.where(inbag[:, None], stats_sorted[order], 0.0)

    cum = jnp.cumsum(contrib, axis=0)
    cum_excl = cum - contrib
    is_start = jnp.concatenate([jnp.ones((1,), bool), lf[1:] != lf[:-1]])
    start_idx = jax.lax.cummax(jnp.where(is_start, jnp.arange(n), -1))
    left = cum_excl - cum_excl[start_idx]                   # per-leaf exclusive prefix
    if h_init is not None:
        left = left + h_init[lf]                            # earlier-shard prefix

    if totals is None:
        assert h_init is None, "row-sharded call must pass GLOBAL totals"
        totals = jax.ops.segment_sum(contrib, lf, num_segments=L1)
    right = totals[lf] - left

    pv = _segmented_cummax_exclusive(jnp.where(inbag, a, NEG), is_start)
    if v_init is not None:
        vi = jnp.where(jnp.isfinite(v_init), v_init, NEG)
        pv = jnp.maximum(pv, vi[lf])
    ok = inbag & cand_leaf[lf] & (a > pv) & jnp.isfinite(pv) \
        & (cnt(left) >= min_records) & (cnt(right) >= min_records)
    gain = jnp.where(ok, split_gain(left, right, impurity), NEG)
    tau = (a + pv) * 0.5

    best_s = jax.ops.segment_max(gain, lf, num_segments=L1)
    best_s = jnp.maximum(best_s, NEG)  # segment_max of empty segment -> -inf already
    # first row achieving the max (scan-order tie-breaking)
    hit = gain >= best_s[lf]
    first = jax.ops.segment_min(jnp.where(hit, jnp.arange(n), n), lf, num_segments=L1)
    best_t = jnp.where(first < n, tau[jnp.minimum(first, n - 1)], 0.0)
    return best_s, best_t


NUMERIC_BACKENDS = {
    "scan": best_numeric_split_scan,
    "segment": best_numeric_split_segment,
}


# ---------------------------------------------------------------------------
# Numerical — leaf-ordered backend (the fused level step's fast path)
# ---------------------------------------------------------------------------
#
# Identical semantics to `best_numeric_split_segment`, but the caller hands
# rows already in (leaf, value)-sorted order, so the per-level counting sort
# (the dominant per-column cost at scale) disappears.  The fused tree
# builder maintains that order incrementally across levels: children of a
# leaf are stable partitions of the parent's contiguous block, an O(n)
# segmented-cumsum update instead of an O(n log n) sort (see tree.py).

def _segmented_first_max(gain: jnp.ndarray, tau: jnp.ndarray,
                         is_start: jnp.ndarray):
    """Inclusive segmented (max, argfirst) scan along the last axis: at each
    row, the best gain seen so far in its segment and the threshold of the
    FIRST row achieving it (scan-order tie-breaking, matching Alg. 1)."""
    def combine(a, b):
        (ga, ta, sa), (gb, tb, sb) = a, b
        take_b = sb | (gb > jnp.where(sb, NEG, ga))
        return (jnp.where(take_b, gb, ga), jnp.where(take_b, tb, ta), sa | sb)
    bs, bt, _ = jax.lax.associative_scan(combine, (gain, tau, is_start),
                                         axis=-1)
    return bs, bt


def best_numeric_split_leaf_ordered(
    vals: jnp.ndarray,           # (m, n) float32, (leaf, value)-sorted rows
    lf_pos: jnp.ndarray,         # (n,) int32 leaf id PER POSITION (shared)
    inbag: jnp.ndarray,          # (m, n) bool: w > 0 & leaf open, per column
    stats: jnp.ndarray,          # (m, n, S) row stats in leaf order
    cand_leaf: jnp.ndarray,      # (m, L+1) bool
    num_leaves: int,
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
    totals: jnp.ndarray | None = None,     # (L+1, S) shared per-leaf totals
    row_counts: jnp.ndarray | None = None,  # (L+1,) rows per leaf (all rows)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact all-columns supersplit over pre-leaf-ordered rows.

    Natively batched over the column axis (no vmap, no per-column sort, no
    scatter-add in the hot path).  Because every column holds the same
    multiset of rows counting-sorted by the same leaf ids, the block
    structure is column-independent: `lf_pos` is the ONE leaf-of-position
    array shared by all columns, and block starts/ends derive from the one
    `row_counts` histogram.

    When `totals` is None the per-leaf totals are reduced from each
    column's own row order (bit-matching the `segment` backend); passing
    the level's shared totals saves the reduction — exact for
    classification, where stats are integer-valued bag counts.  Returns
    (best_gain, best_threshold), each (m, L+1).
    """
    m, n = vals.shape
    L1 = num_leaves + 1
    cnt = count_fn(task)
    if row_counts is None:
        row_counts = jax.ops.segment_sum(
            jnp.ones((n,), jnp.int32), lf_pos, num_segments=L1)

    contrib = jnp.where(inbag[..., None], stats, 0.0)
    cum = jnp.cumsum(contrib, axis=1)
    cum_excl = cum - contrib
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), lf_pos[1:] != lf_pos[:-1]])   # (n,) shared
    start_idx = jax.lax.cummax(jnp.where(is_start, jnp.arange(n), -1))
    left = cum_excl - cum_excl[:, start_idx, :]              # excl prefix
    if totals is None:
        flat = jnp.arange(m)[:, None] * L1 + lf_pos[None]
        totals_cols = jax.ops.segment_sum(
            contrib.reshape(m * n, -1), flat.reshape(-1),
            num_segments=m * L1, indices_are_sorted=True).reshape(m, L1, -1)
        parent = totals_cols[:, lf_pos, :]                   # (m, n, S)
    else:
        parent = totals[lf_pos][None]                        # shared (1,n,S)
    right = parent - left

    is_start_b = jnp.broadcast_to(is_start[None], (m, n))
    pv = _segmented_cummax_exclusive_2d(
        jnp.where(inbag, vals, NEG), is_start_b)
    ok = inbag & cand_leaf[:, lf_pos] & (vals > pv) & jnp.isfinite(pv) \
        & (cnt(left) >= min_records) & (cnt(right) >= min_records)
    # parent impurity is recomputed from left + right per row, NOT from the
    # gathered per-leaf totals: the values agree, but evaluating the
    # impurity at a different array shape can flip the last ulp of
    # transcendentals (entropy's log), and the reference backend computes
    # it exactly this way
    gain = jnp.where(ok, split_gain(left, right, impurity), NEG)
    tau = (vals + pv) * 0.5

    # Materialize gain/tau before the log-depth scan: without the barrier
    # XLA re-fuses (and so re-computes) the whole producer chain into every
    # scan level — a ~6x blowup measured on CPU.
    gain, tau = jax.lax.optimization_barrier((gain, tau))
    bs, bt = _segmented_first_max(gain, tau, is_start_b)
    # each leaf's best sits at its block's LAST row; block ends follow from
    # the (column-independent) leaf histogram — a gather, not a scatter
    end_pos = jnp.maximum(jnp.cumsum(row_counts) - 1, 0)     # (L+1,)
    occupied = row_counts > 0
    best_s = jnp.where(occupied[None, :], bs[:, end_pos], NEG)
    best_t = jnp.where(occupied[None, :], bt[:, end_pos], 0.0)
    return best_s, best_t


# ---------------------------------------------------------------------------
# Numerical — PLANET-style histogram (approximate) mode
# ---------------------------------------------------------------------------

def best_numeric_split_histogram(
    table: jnp.ndarray,          # (L+1, B, S) per-leaf (bin × stat) table
    cand_leaf: jnp.ndarray,      # (L+1,) bool
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Approximate supersplit: score only the B−1 bucket boundaries.

    The PLANET-style contrast baseline to the paper's exact search
    (`split_mode="hist"`): the numeric column was quantized once at presort
    time into <= B quantile buckets (presort.quantize_edges), every level
    builds the per-leaf (bin × stat) count `table` with the SAME scatter-add
    machinery as the categorical path (`feature_count_tables` / the Pallas
    `feat_hist` kernel), and this scorer enumerates prefix cuts in bucket
    order — no reordering, buckets are already value-sorted, which is the
    only difference from `best_categorical_split_from_table`.

    Returns (best_gain (L+1,), best_cut (L+1,) float32) — best_cut is the
    winning BIN INDEX b (a cut keeps bins <= b left), not a float
    threshold: the level program never touches the float edges (the bin
    cache is its only per-row numeric input, DESIGN.md §6), and the host
    decodes `threshold = edges[col, b]` when recording the node, which
    reproduces the scored partition exactly (`bin <= b  <=>  x <=
    edges[b]`).  Empty buckets (duplicate edges) give zero-gain duplicate
    cuts and are never selected over a populated boundary.
    """
    totals = table.sum(1)                                   # (L+1, S)
    cnt = count_fn(task)
    prefix = jnp.cumsum(table, axis=1)                      # cut after bin b
    left = prefix[:, :-1, :]                                # cuts 0..B-2
    right = totals[:, None, :] - left
    ok = (cnt(left) >= min_records) & (cnt(right) >= min_records) \
        & cand_leaf[:, None]
    gains = jnp.where(ok, split_gain(left, right, impurity), NEG)  # (L+1, B-1)
    best_cut = jnp.argmax(gains, axis=1)                    # first max
    best_gain = jnp.take_along_axis(gains, best_cut[:, None], axis=1)[:, 0]
    best_cut = jnp.where(jnp.isfinite(best_gain), best_cut, 0)
    return best_gain, best_cut.astype(jnp.float32)


def feature_count_tables(
    bin_of: jnp.ndarray,         # (m, n) packed bucket ids (uint8/uint16)
    leaf_ids: jnp.ndarray,       # (n,) int32 scatter slots, 0 = discard
    w: jnp.ndarray,              # (n,) float32 bag weights
    stats: jnp.ndarray,          # (n, S) row stats
    num_slots: int,              # table width minus one (slots 1..num_slots)
    num_bins: int,
) -> jnp.ndarray:
    """(m, num_slots+1, B, S) per-leaf bin tables for ALL m features in ONE
    scatter over the flat (feature, slot, bin) index space.

    This is the jnp twin of the Pallas `feat_hist` kernel (kernels/ops
    .feature_tables): both accumulate each row's stat contribution into
    every feature's (slot, bin) cell in row order, so the two backends
    produce the same tables (bit-identical for the integer-valued
    classification stats).  The single flat segment_sum replaces the old
    per-column vmap of `categorical_count_table` — one scatter pass over
    the whole bin cache instead of m dispatched column scatters.

    `leaf_ids` are pre-mapped scatter SLOTS, not necessarily raw leaf ids:
    the subtraction path (level/engines.py) passes the packed build-leaf
    slots with derive-leaf rows mapped to the discarded slot 0.
    """
    m, n = bin_of.shape
    W = num_slots + 1
    inbag = (w > 0) & (leaf_ids > 0)
    contrib = jnp.where(inbag[:, None], stats, 0.0)          # (n, S)
    base = leaf_ids.astype(jnp.int32) * num_bins + bin_of.astype(jnp.int32)
    flat = (jnp.arange(m, dtype=jnp.int32)[:, None] * (W * num_bins)
            + base)                                          # (m, n)
    contrib_b = jnp.broadcast_to(contrib[None], (m, n, contrib.shape[-1]))
    table = jax.ops.segment_sum(contrib_b.reshape(m * n, -1),
                                flat.reshape(-1),
                                num_segments=m * W * num_bins)
    return table.reshape(m, W, num_bins, -1)


# ---------------------------------------------------------------------------
# Categorical — count tables + Breiman ordering (paper §2.4, SM)
# ---------------------------------------------------------------------------

def categorical_count_table(
    x_col: jnp.ndarray,          # (n,) int32 category values
    leaf_of: jnp.ndarray,        # (n,) int32 in [0, L]
    w: jnp.ndarray,              # (n,) float32
    stats: jnp.ndarray,          # (n, S)
    num_leaves: int,
    arity: int,
) -> jnp.ndarray:
    """The paper's 'attribute value x class -> count' table, (L+1, V, S)."""
    L1 = num_leaves + 1
    inbag = (w > 0) & (leaf_of > 0)
    contrib = jnp.where(inbag[:, None], stats, 0.0)
    flat = leaf_of * arity + x_col
    table = jax.ops.segment_sum(contrib, flat, num_segments=L1 * arity)
    return table.reshape(L1, arity, -1)


def best_categorical_split_from_table(
    table: jnp.ndarray,          # (L+1, V, S) per-leaf count table
    cand_leaf: jnp.ndarray,      # (L+1,) bool
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Breiman ordering + ordered prefix cuts on a prebuilt count table.

    Shared scoring for the jnp path (`best_categorical_split`) and the
    Pallas `cat_hist` kernel path (kernels/ops.categorical_tables) — the
    table layout is identical, so the two backends give identical splits.

    Args:
      table:     (L+1, V, S) per-(leaf, category) stat sums — bag-weighted
                 one-hot class counts (S = C, classification) or
                 [w, wy, wy²] (S = 3, regression).  Row 0 (the closed-leaf
                 sentinel) is ignored.  V may include padded categories;
                 they are empty (all-zero) and sort last, so cuts only
                 enumerate populated prefixes.
      cand_leaf: (L+1,) bool — leaves for which this feature is a
                 candidate; others return gain −inf.
      impurity/task/min_records: as for the numeric engines; both children
                 of a reported cut have >= min_records in-bag weight.

    Categories are ordered per leaf by the Breiman metric — P(last class |
    v) for classification (exact for binary), mean(y | v) for regression
    (exact for L2) — and only the V−1 ordered prefix cuts are scored: the
    optimal subset split for those cases at O(V log V) instead of 2^V.

    Returns (best_gain (L+1,), mask (L+1, V) bool); mask True sends the
    category to the LEFT child.  Under `tree.build_forest` this whole
    search is vmapped over a leading tree axis.
    """
    arity = table.shape[1]
    totals = table.sum(1)                                   # (L+1, S)
    cnt = count_fn(task)

    tc = cnt(table)                                         # (L+1, V) counts
    if task == "classification":
        metric = table[..., -1] / jnp.maximum(tc, 1e-12)
    else:
        metric = table[..., 1] / jnp.maximum(tc, 1e-12)
    # Put empty categories last so cuts enumerate only populated prefixes.
    metric = jnp.where(tc > 0, metric, jnp.inf)
    order = jnp.argsort(metric, axis=1)                     # (L+1, V)
    sorted_table = jnp.take_along_axis(table, order[..., None], axis=1)
    prefix = jnp.cumsum(sorted_table, axis=1)               # inclusive: cut after pos v
    left = prefix[:, :-1, :]                                # cuts 0..V-2
    right = totals[:, None, :] - left
    ok = (cnt(left) >= min_records) & (cnt(right) >= min_records) \
        & cand_leaf[:, None]
    gains = jnp.where(ok, split_gain(left, right, impurity), NEG)  # (L+1, V-1)

    best_cut = jnp.argmax(gains, axis=1)                    # first max: argmax picks first
    best_gain = jnp.take_along_axis(gains, best_cut[:, None], axis=1)[:, 0]
    # mask in ordered space: positions <= cut; scatter back to category space
    pos = jnp.arange(arity)[None, :]
    in_left_sorted = pos <= best_cut[:, None]
    mask = jnp.take_along_axis(
        in_left_sorted, jnp.argsort(order, axis=1), axis=1)  # inverse perm
    return best_gain, mask


@functools.partial(jax.jit, static_argnames=("num_leaves", "arity", "impurity", "task"))
def best_categorical_split(
    x_col: jnp.ndarray,          # (n,) int32 category values
    leaf_of: jnp.ndarray,        # (n,) int32 in [0, L]
    w: jnp.ndarray,              # (n,) float32
    stats: jnp.ndarray,          # (n, S)
    cand_leaf: jnp.ndarray,      # (L+1,) bool
    num_leaves: int,
    arity: int,
    impurity: str = "gini",
    task: str = "classification",
    min_records: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Best subset split x ∈ C per open leaf, one pass.

    Builds the (leaf × category × stat) count table the paper describes for
    categorical attributes, then orders categories per leaf by the Breiman
    metric (P(last class | v) for classification — exact for binary
    classification; mean(y|v) for regression — exact for L2) and scans the
    ordered prefix cuts.

    Returns (best_gain (L+1,), best_mask (L+1, arity) bool) — mask True means
    the category goes to the LEFT child.
    """
    table = categorical_count_table(x_col, leaf_of, w, stats, num_leaves, arity)
    return best_categorical_split_from_table(
        table, cand_leaf, impurity, task, min_records)
