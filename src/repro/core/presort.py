"""Dataset preparation: presorting of numerical attributes (paper §2.1).

"Consistently with existing works, we use presorting for numerical
attributes" — the single most expensive preparation step. Done once; every
tree and every depth level reuses it. On the distributed mesh the presort
is a sharded `argsort` per column (the paper's external sort becomes XLA's
distributed sort); rows of the sorted order are range-partitioned over the
"data" axis so each shard owns a contiguous slice of every sorted column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def presort_columns(num: jnp.ndarray) -> jnp.ndarray:
    """argsort each numerical column.

    Args:
      num: (n, m_num) float32.
    Returns:
      sorted_idx: (m_num, n) int32 — row indices in increasing value order,
      stable (ties keep original row order, making runs reproducible).
    """
    return jnp.argsort(num.T, axis=-1, stable=True).astype(jnp.int32)


def gather_sorted(num: jnp.ndarray, sorted_idx: jnp.ndarray) -> jnp.ndarray:
    """Materialize the sorted values: (m_num, n) float32."""
    return jnp.take_along_axis(num.T, sorted_idx, axis=-1)
