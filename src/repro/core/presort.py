"""Dataset preparation: presorting of numerical attributes (paper §2.1).

"Consistently with existing works, we use presorting for numerical
attributes" — the single most expensive preparation step. Done once; every
tree and every depth level reuses it. On the distributed mesh the presort
is a sharded `argsort` per column (the paper's external sort becomes XLA's
distributed sort); rows of the sorted order are range-partitioned over the
"data" axis so each shard owns a contiguous slice of every sorted column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=())
def presort_columns(num: jnp.ndarray) -> jnp.ndarray:
    """argsort each numerical column.

    Args:
      num: (n, m_num) float32.
    Returns:
      sorted_idx: (m_num, n) int32 — row indices in increasing value order,
      stable (ties keep original row order, making runs reproducible).
    """
    return jnp.argsort(num.T, axis=-1, stable=True).astype(jnp.int32)


def gather_sorted(num: jnp.ndarray, sorted_idx: jnp.ndarray) -> jnp.ndarray:
    """Materialize the sorted values: (m_num, n) float32."""
    return jnp.take_along_axis(num.T, sorted_idx, axis=-1)


# ---------------------------------------------------------------------------
# PLANET-style threshold buckets (the approximate contrast baseline)
# ---------------------------------------------------------------------------
#
# The paper's central claim is that DRF stays EXACT where PLANET-era systems
# quantize numeric columns into fixed bins.  `split_mode="hist"` reproduces
# that baseline inside the same fused level machinery: each numeric column
# is bucketed ONCE at presort time into <= num_bins quantile buckets, and
# every level scores only the bucket boundaries from per-leaf (bin × class)
# count tables (splits.best_numeric_split_histogram) instead of every
# midpoint between consecutive values.

@functools.partial(jax.jit, static_argnames=("num_bins",))
def quantize_edges(sorted_vals: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Per-column bucket upper edges from the presorted values.

    Args:
      sorted_vals: (m_num, n) float32, each row ascending (gather_sorted).
      num_bins:    bucket count B (PLANET-style fixed budget, e.g. 255).
    Returns:
      edges: (m_num, B) float32 — edges[j, b] is the LARGEST value of
      column j falling in bucket b (equi-depth quantile positions, so every
      bucket holds ~n/B rows; edges[j, B-1] is the column max).  The bucket
      rule is  b(x) = number of lower edges strictly below x, so the
      candidate threshold for
      a cut after bucket b is exactly edges[j, b] with the tree's usual
      `x <= thr` condition — training-time bucket partitions and
      inference-time threshold partitions agree EXACTLY.  Duplicate edges
      (heavy ties / constant columns) simply leave empty buckets, which
      score as zero-gain cuts and are never selected.
    """
    n = sorted_vals.shape[1]
    pos = (jnp.arange(1, num_bins + 1) * n) // num_bins - 1   # (B,)
    pos = jnp.clip(pos, 0, n - 1)
    return sorted_vals[:, pos]


def bin_dtype(num_bins: int):
    """The bit-packed bucket-id dtype: bin ids live in [0, num_bins).

    uint8 up to 256 buckets (the PLANET-standard 255-bin budget included),
    uint16 past that — the bin cache is the ONLY per-row numeric state the
    hist-mode level program reads (DESIGN.md §6), so packing it is a 4x
    memory-traffic cut over the old int32 ids (and 4x over re-reading the
    float32 columns).
    """
    return jnp.uint8 if num_bins <= 256 else jnp.uint16


@jax.jit
def bin_columns(num: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Bucket id per row per column: (n, m_num) values -> (m_num, n) packed.

    bin_of[j, k] = searchsorted(edges[j, :-1], num[k, j], side="left"), i.e.
    the first bucket whose upper edge is >= the value; values above the
    column max (unseen at fit time) land in the last bucket.  The result is
    bit-packed (`bin_dtype`): uint8 for <= 256 buckets, uint16 beyond.
    """
    dt = bin_dtype(edges.shape[1])

    def per_col(v, e):
        return jnp.searchsorted(e[:-1], v, side="left").astype(dt)
    return jax.vmap(per_col)(num.T, edges)


def quantize(num: jnp.ndarray, sorted_vals: jnp.ndarray,
             num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The full hist-mode bucket state from an existing presort.

    The one quantization recipe shared by `RandomForest.fit`,
    `GBTModel.fit` and `TabularDataset.quantize`.  Returns
    (bin_of (m_num, n) uint8/uint16 — see `bin_dtype`,
    edges (m_num, num_bins) float32).  `bin_of` is the device-resident bin
    cache every hist level reads; `edges` only decodes winning cut indices
    back to float thresholds on the HOST (tree.py), so no float32 column
    traffic remains inside the level program.
    """
    edges = quantize_edges(sorted_vals, num_bins)
    return bin_columns(num, edges), edges


# ---------------------------------------------------------------------------
# Chunked (out-of-core) quantization — DESIGN.md §8
# ---------------------------------------------------------------------------
#
# `quantize_edges` reads the fully presorted columns; for datasets that
# never fit in memory the SAME order-statistic edges are found by a
# multi-pass radix select over chunked column blocks: float32 values map
# to order-preserving uint32 keys, pass 1 histograms the top 16 key bits
# per column, and two refinement passes (8 bits each) narrow only the
# <= num_bins prefixes a quantile still needs — three sequential passes
# over the data, O(m·B) state, and edges that are BIT-EQUAL to
# `quantize_edges(gather_sorted(...))` (asserted by the streaming parity
# suite).  Caveats of the key order: NaNs are not supported, and a column
# mixing -0.0/+0.0 exactly at a quantile position may differ in the sign
# of the zero edge (the values still compare equal, so binning agrees).

_KEY_GROUPS = (16, 8, 8)            # bit-group widths, high to low


def _float_keys(block: np.ndarray) -> np.ndarray:
    """Order-preserving uint32 keys for a float32 block (same shape)."""
    b = np.ascontiguousarray(block, np.float32).view(np.uint32)
    return np.where(b & 0x80000000, ~b, b ^ 0x80000000).astype(np.uint32)


def _keys_to_float(keys: np.ndarray) -> np.ndarray:
    """Invert `_float_keys`: uint32 keys back to float32 values."""
    k = np.asarray(keys, np.uint32)
    b = np.where(k & 0x80000000, k ^ 0x80000000, ~k).astype(np.uint32)
    return b.view(np.float32)


def streaming_quantile_edges(chunks, n: int, m_num: int,
                             num_bins: int) -> np.ndarray:
    """Exact per-column quantile edges from chunked column blocks.

    Args:
      chunks:   re-iterable callable; each call returns an iterator of
                (c, m_num) float32 row blocks covering the n rows in
                order.  Iterated once per radix pass (3 passes).
      n/m_num:  total rows / numeric columns.
      num_bins: bucket budget B.
    Returns:
      edges (m_num, B) float32 — bit-equal to
      `quantize_edges(gather_sorted(num, presort_columns(num)), B)` (the
      in-memory recipe) at the same order-statistic positions
      pos = clip((arange(1, B+1)·n)//B − 1, 0, n−1).
    """
    assert n > 0 and m_num > 0
    pos = (np.arange(1, num_bins + 1, dtype=np.int64) * n) // num_bins - 1
    pos = np.clip(pos, 0, n - 1)
    rank = np.broadcast_to(pos + 1, (m_num, num_bins)).astype(np.int64)
    rank = rank.copy()                       # remaining rank inside prefix
    pref = np.zeros((m_num, num_bins), np.int64)   # resolved high bits
    done = 0
    for g, width in enumerate(_KEY_GROUPS):
        shift = 32 - done - width
        size = 1 << width
        if g == 0:
            counts = np.zeros((m_num, size), np.int64)
            for block in chunks():
                keys = _float_keys(block) >> np.uint32(shift)
                for j in range(m_num):
                    counts[j] += np.bincount(keys[:, j], minlength=size)
            for j in range(m_num):
                cum = np.cumsum(counts[j])
                gsel = np.searchsorted(cum, rank[j], side="left")
                rank[j] -= np.where(gsel > 0, cum[gsel - 1], 0)
                pref[j] = gsel
        else:
            # refine only the prefixes some quantile still needs
            uniq = [np.unique(pref[j]) for j in range(m_num)]
            P = max(len(u) for u in uniq)
            counts = np.zeros((m_num, P, size), np.int64)
            mask = size - 1
            for block in chunks():
                keys = _float_keys(block)
                hi = keys >> np.uint32(shift + width)
                sub = (keys >> np.uint32(shift)).astype(np.int64) & mask
                for j in range(m_num):
                    u = uniq[j]
                    idx = np.searchsorted(u, hi[:, j])
                    idx_c = np.minimum(idx, len(u) - 1)
                    match = u[idx_c] == hi[:, j]
                    flat = idx_c[match] * size + sub[:, j][match]
                    counts[j] += np.bincount(
                        flat, minlength=P * size).reshape(P, size)
            for j in range(m_num):
                pi = np.searchsorted(uniq[j], pref[j])
                cum = np.cumsum(counts[j], axis=1)[pi]      # (B, size)
                gsel = (cum < rank[j][:, None]).sum(1)
                before = np.where(gsel > 0,
                                  cum[np.arange(num_bins), gsel - 1], 0)
                rank[j] -= before
                pref[j] = (pref[j] << width) | gsel
        done += width
    return _keys_to_float(pref.astype(np.uint32))


def bin_block(block: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Host-side chunk binning: (c, m_num) float32 -> (m_num, c) packed.

    The numpy twin of `bin_columns` for RowSource chunk streams — same
    rule (`searchsorted(edges[j, :-1], v, side="left")`, values above the
    column max land in the last bucket), same `bin_dtype` packing, so a
    chunk-binned cache is bit-equal to the in-memory one.
    """
    m_num, B = edges.shape
    dt = np.uint8 if B <= 256 else np.uint16
    out = np.empty((m_num, block.shape[0]), dt)
    for j in range(m_num):
        out[j] = np.searchsorted(edges[j, :-1], block[:, j], side="left")
    return out
