"""Dataset preparation: presorting of numerical attributes (paper §2.1).

"Consistently with existing works, we use presorting for numerical
attributes" — the single most expensive preparation step. Done once; every
tree and every depth level reuses it. On the distributed mesh the presort
is a sharded `argsort` per column (the paper's external sort becomes XLA's
distributed sort); rows of the sorted order are range-partitioned over the
"data" axis so each shard owns a contiguous slice of every sorted column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def presort_columns(num: jnp.ndarray) -> jnp.ndarray:
    """argsort each numerical column.

    Args:
      num: (n, m_num) float32.
    Returns:
      sorted_idx: (m_num, n) int32 — row indices in increasing value order,
      stable (ties keep original row order, making runs reproducible).
    """
    return jnp.argsort(num.T, axis=-1, stable=True).astype(jnp.int32)


def gather_sorted(num: jnp.ndarray, sorted_idx: jnp.ndarray) -> jnp.ndarray:
    """Materialize the sorted values: (m_num, n) float32."""
    return jnp.take_along_axis(num.T, sorted_idx, axis=-1)


# ---------------------------------------------------------------------------
# PLANET-style threshold buckets (the approximate contrast baseline)
# ---------------------------------------------------------------------------
#
# The paper's central claim is that DRF stays EXACT where PLANET-era systems
# quantize numeric columns into fixed bins.  `split_mode="hist"` reproduces
# that baseline inside the same fused level machinery: each numeric column
# is bucketed ONCE at presort time into <= num_bins quantile buckets, and
# every level scores only the bucket boundaries from per-leaf (bin × class)
# count tables (splits.best_numeric_split_histogram) instead of every
# midpoint between consecutive values.

@functools.partial(jax.jit, static_argnames=("num_bins",))
def quantize_edges(sorted_vals: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Per-column bucket upper edges from the presorted values.

    Args:
      sorted_vals: (m_num, n) float32, each row ascending (gather_sorted).
      num_bins:    bucket count B (PLANET-style fixed budget, e.g. 255).
    Returns:
      edges: (m_num, B) float32 — edges[j, b] is the LARGEST value of
      column j falling in bucket b (equi-depth quantile positions, so every
      bucket holds ~n/B rows; edges[j, B-1] is the column max).  The bucket
      rule is  b(x) = number of lower edges strictly below x, so the
      candidate threshold for
      a cut after bucket b is exactly edges[j, b] with the tree's usual
      `x <= thr` condition — training-time bucket partitions and
      inference-time threshold partitions agree EXACTLY.  Duplicate edges
      (heavy ties / constant columns) simply leave empty buckets, which
      score as zero-gain cuts and are never selected.
    """
    n = sorted_vals.shape[1]
    pos = (jnp.arange(1, num_bins + 1) * n) // num_bins - 1   # (B,)
    pos = jnp.clip(pos, 0, n - 1)
    return sorted_vals[:, pos]


def bin_dtype(num_bins: int):
    """The bit-packed bucket-id dtype: bin ids live in [0, num_bins).

    uint8 up to 256 buckets (the PLANET-standard 255-bin budget included),
    uint16 past that — the bin cache is the ONLY per-row numeric state the
    hist-mode level program reads (DESIGN.md §6), so packing it is a 4x
    memory-traffic cut over the old int32 ids (and 4x over re-reading the
    float32 columns).
    """
    return jnp.uint8 if num_bins <= 256 else jnp.uint16


@jax.jit
def bin_columns(num: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Bucket id per row per column: (n, m_num) values -> (m_num, n) packed.

    bin_of[j, k] = searchsorted(edges[j, :-1], num[k, j], side="left"), i.e.
    the first bucket whose upper edge is >= the value; values above the
    column max (unseen at fit time) land in the last bucket.  The result is
    bit-packed (`bin_dtype`): uint8 for <= 256 buckets, uint16 beyond.
    """
    dt = bin_dtype(edges.shape[1])

    def per_col(v, e):
        return jnp.searchsorted(e[:-1], v, side="left").astype(dt)
    return jax.vmap(per_col)(num.T, edges)


def quantize(num: jnp.ndarray, sorted_vals: jnp.ndarray,
             num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The full hist-mode bucket state from an existing presort.

    The one quantization recipe shared by `RandomForest.fit`,
    `GBTModel.fit` and `TabularDataset.quantize`.  Returns
    (bin_of (m_num, n) uint8/uint16 — see `bin_dtype`,
    edges (m_num, num_bins) float32).  `bin_of` is the device-resident bin
    cache every hist level reads; `edges` only decodes winning cut indices
    back to float thresholds on the HOST (tree.py), so no float32 column
    traffic remains inside the level program.
    """
    edges = quantize_edges(sorted_vals, num_bins)
    return bin_columns(num, edges), edges
