"""Bit-packed sample→leaf mapping (paper §2.3).

"DRF monitors the number ℓ of active leaves ... ⌈log2(ℓ+1)⌉ bits of
information are needed to index a leaf [plus the closed-leaf sentinel].
Therefore this mapping requires n⌈log2(ℓ+1)⌉ bits of memory."

We honor the paper's memory bound with a packed uint32 representation:
`values_per_word = 32 // bits` leaf ids per word (no word-straddling, which
keeps pack/unpack fully vectorized on TPU lanes; the padding waste is at
most bits-1 < 6 bits per word for realistic ℓ).

Sentinel: leaf id 0 is reserved for "in a closed leaf"; open leaves are
1..ℓ.  The unpacked working copy used inside the supersplit kernels is a
plain int32 array — packing is for storage/transport, exactly the role the
class list plays in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

CLOSED = 0  # sentinel leaf id


def bits_needed(num_open_leaves: int) -> int:
    """⌈log2(ℓ+1)⌉, minimum 1."""
    return max(1, int(jnp.ceil(jnp.log2(num_open_leaves + 1))))


@functools.partial(jax.jit, static_argnames=("bits",))
def pack(leaf_ids: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack (n,) int32 leaf ids (< 2**bits) into uint32 words."""
    vpw = 32 // bits
    n = leaf_ids.shape[0]
    pad = (-n) % vpw
    ids = jnp.pad(leaf_ids.astype(jnp.uint32), (0, pad)).reshape(-1, vpw)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, :]
    return jnp.bitwise_or.reduce(ids << shifts, axis=1)


@functools.partial(jax.jit, static_argnames=("bits", "n"))
def unpack(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Inverse of `pack`; returns (n,) int32."""
    vpw = 32 // bits
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, :]
    mask = jnp.uint32((1 << bits) - 1)
    vals = (words[:, None] >> shifts) & mask
    return vals.reshape(-1)[:n].astype(jnp.int32)


def packed_words(n: int, bits: int) -> int:
    vpw = 32 // bits
    return -(-n // vpw)


def storage_bits(n: int, num_open_leaves: int) -> int:
    """The paper's memory bound for the mapping (reported in benchmarks)."""
    return n * bits_needed(num_open_leaves)
