"""DRF distribution on the TPU mesh (paper §2 worker topology → shard_map).

The mesh machinery now lives in `repro.core.level.sharded` as
`SplitEngine`s — the same engine objects plug into the ONE level plan that
local training uses, so sharded training inherits the multi-tree batch
axis, early-finish masking and device-resident pruning of
`tree.build_forest` (DESIGN.md §5/§7).  This module keeps the historical
factory entry points (each returns the corresponding engine; the engines
are also callable with the original `supersplit_fn` signatures) plus the
pieces that never were engines: the 1-bit condition broadcast and the
dry-run level step.

Topology mapping (DESIGN.md §5):

  * "model" axis  = the splitters: feature columns are sharded over it, each
    device searching optimal splits only on its own columns (paper: "each
    worker is assigned to a subset of columns ... read sequentially").
  * "data" axis   = row shards — range-partitions of the PRESORTED order
    for the exact engine (beyond-paper 2-D extension), plain row order for
    the histogram/categorical table engines.
  * partial supersplit merge = the gains all_gather / table psum (the
    paper's tree builder "comparing the answers of the splitters").
  * condition evaluation    = 1 bit per sample, psum over "model" (only the
    winning column's owner contributes) — the paper's "Dn bits in D
    allreduce" per tree.

All engines are shard_map'd and composable under jit, so the SAME code
lowers for the 16×16 single-pod and (2,16,16) multi-pod production meshes
in launch/dryrun.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import splits
from repro.core.level.sharded import (ShardedCategorical,  # noqa: F401
                                      ShardedExactNumeric,
                                      ShardedHistNumeric, _shmap, shard_map)


def make_column_sharded_supersplit(mesh, feature_axis: str = "model"):
    """Exact engine, columns sharded over `feature_axis`, rows replicated —
    the paper's splitter memory layout ("Sliq/R and DRF duplicate the class
    list in each worker")."""
    return ShardedExactNumeric(mesh=mesh, feature_axis=feature_axis,
                               row_axis=None)


def make_2d_sharded_supersplit(mesh, feature_axis: str = "model",
                               row_axis: str = "data",
                               backend: str = "segment"):
    """Exact engine with BOTH axes sharded (beyond-paper extension): row
    shards resume the presorted scan from the previous shard's
    all_gathered histogram/value state — see
    `level.sharded.ShardedExactNumeric`."""
    return ShardedExactNumeric(mesh=mesh, feature_axis=feature_axis,
                               row_axis=row_axis, backend=backend)


def make_hist_sharded_supersplit(mesh, feature_axis: str = "model",
                                 row_axis="data"):
    """Histogram engine for `split_mode="hist"`: per-shard (bin × stat)
    tables merged by ONE psum of (L+1)·B·S floats per column — the paper's
    network-complexity contrast with the exact all_gather, executable side
    by side (DESIGN.md §6)."""
    return ShardedHistNumeric(mesh=mesh, feature_axis=feature_axis,
                              row_axis=row_axis)


def make_categorical_sharded_supersplit(mesh, feature_axis: str = "model",
                                        row_axis="data"):
    """Categorical table engine under the mesh (order-free psum merge);
    requires m_cat divisible by the feature-axis size."""
    return ShardedCategorical(mesh=mesh, feature_axis=feature_axis,
                              row_axis=row_axis)


# ---------------------------------------------------------------------------
# 1-bit condition broadcast (Alg. 2 steps 5/7) under the mesh
# ---------------------------------------------------------------------------

def make_sharded_evaluate(mesh, feature_axis: str = "model"):
    """Winning-condition evaluation: the owner of the winning column computes
    the bit; a psum over the splitter axis broadcasts it (n bits per level —
    the paper's Table 1 network row for DRF)."""

    def fn(num_cols, leaf_of, feat_of_leaf, thr_of_leaf, m_num):
        # num_cols: (m_num, n) raw columns sharded over feature_axis.
        def local(cols, leaf_of, feat_of_leaf, thr_of_leaf):
            k = jax.lax.axis_index(feature_axis)
            mloc = cols.shape[0]
            lo = k * mloc
            f = feat_of_leaf[leaf_of]                       # global feature id
            mine = (f >= lo) & (f < lo + mloc)
            jloc = jnp.clip(f - lo, 0, mloc - 1)
            x = cols[jloc, jnp.arange(cols.shape[1])]
            bit = mine & (x <= thr_of_leaf[leaf_of])
            return jax.lax.psum(bit.astype(jnp.uint8), feature_axis)

        sharded = _shmap(
            local, mesh,
            in_specs=(P(feature_axis, None), P(None), P(None), P(None)),
            out_specs=P(None))
        return sharded(num_cols, leaf_of, feat_of_leaf, thr_of_leaf) > 0

    return fn


# ---------------------------------------------------------------------------
# One DRF level as a single jittable step (the dry-run / roofline workload)
# ---------------------------------------------------------------------------

def drf_level_step_fn(mesh, *, num_leaves: int, num_classes: int,
                      impurity: str = "gini", backend: str = "segment",
                      feature_axis: str = "model", row_axis: str = "data"):
    """Build the jittable 'one depth level of DRF' step used by launch/dryrun.

    Inputs (see launch/specs): sorted_vals/sorted_idx (m, n) sharded
    (feature_axis, row_axis); leaf_of (n,), labels (n,), w (n,) sharded
    (row_axis,).  Output: per-(feature, leaf) best gains/thresholds plus the
    winning per-leaf split — i.e. Alg. 2 step 3 for one level, end to end.
    """
    sup = make_2d_sharded_supersplit(mesh, feature_axis, row_axis, backend)

    def step(sorted_vals, sorted_idx, leaf_of, labels, w, cand):
        stats = splits.row_stats(labels, w, num_classes, "classification")
        gains, thr = sup(sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                         num_leaves, impurity, "classification", 1.0)
        best_feat = jnp.argmax(gains, axis=0)               # (L+1,)
        best_gain = jnp.max(gains, axis=0)
        best_thr = jnp.take_along_axis(thr, best_feat[None], 0)[0]
        return best_feat.astype(jnp.int32), best_gain, best_thr

    return step
