"""DRF distribution on the TPU mesh (paper §2 worker topology → shard_map).

Topology mapping (DESIGN.md §5):

  * "model" axis  = the splitters: feature columns are sharded over it, each
    device searching optimal splits only on its own columns (paper: "each
    worker is assigned to a subset of columns ... read sequentially").
  * "data" axis   = row range-partitions of the PRESORTED order (beyond-paper
    2-D extension): shard r of a column holds sorted rows [r·n/w, (r+1)·n/w).
    Exactness is preserved by resuming each shard's pass from the previous
    shard's histogram/value state — an all_gather of (ℓ+1)·S floats per leaf
    histogram, tiny compared to the data.
  * partial supersplit merge = the gains all_gather (the paper's tree builder
    "comparing the answers of the splitters").
  * condition evaluation    = 1 bit per sample, psum over "model" (only the
    winning column's owner contributes) — the paper's "Dn bits in D
    allreduce" per tree.

All functions here are shard_map'd and composable under jit, so the SAME
code lowers for the 16×16 single-pod and (2,16,16) multi-pod production
meshes in launch/dryrun.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax>=0.6 stable name, fall back to experimental
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod.shard_map if hasattr(_shard_map_mod, "shard_map") else _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from repro.core import splits


def _shmap(f, mesh, in_specs, out_specs):
    try:    # jax>=0.6 spells the replication check "check_vma"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:  # jax 0.4.x spells it "check_rep"
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Column-sharded supersplit (the paper's splitter layout, Sliq/R style)
# ---------------------------------------------------------------------------

def make_column_sharded_supersplit(mesh, feature_axis: str = "model"):
    """supersplit_fn for tree.build_tree: columns sharded over `feature_axis`.

    Row state (class list, bag weights, stats) is replicated — exactly the
    paper's splitter memory layout ("Sliq/R and DRF duplicate the class list
    in each worker").
    """
    def fn(sorted_vals, sorted_idx, leaf_of, w, stats, cand, Lp,
           impurity, task, min_records):
        backend = splits.best_numeric_split_segment

        def local(sv, si, cl, leaf_of, w, stats):
            def per_col(v, s, c):
                lf, ww, st = leaf_of[s], w[s], stats[s]
                return backend(v, lf, ww, st, c, Lp, impurity, task, min_records)
            return jax.vmap(per_col)(sv, si, cl)

        sharded = _shmap(
            local, mesh,
            in_specs=(P(feature_axis, None), P(feature_axis, None),
                      P(feature_axis, None), P(None), P(None), P(None, None)),
            out_specs=(P(feature_axis, None), P(feature_axis, None)))
        return sharded(sorted_vals, sorted_idx, cand, leaf_of, w, stats)

    return fn


# ---------------------------------------------------------------------------
# 2-D sharded supersplit: columns over "model", presorted rows over "data"
# ---------------------------------------------------------------------------

def make_2d_sharded_supersplit(mesh, feature_axis: str = "model",
                               row_axis: str = "data",
                               backend: str = "segment"):
    """Exact supersplit with BOTH axes sharded (beyond-paper extension).

    Per column: each row shard computes (a) its local per-leaf stat totals
    and last in-bag value, (b) all_gathers them over `row_axis` (payload
    (L+1)·S floats — independent of n), (c) forms the exclusive shard prefix
    (h_init, v_init) and GLOBAL totals, and (d) runs the exact backend on its
    local slice resuming from that state.  Partial bests are merged with a
    lexicographic (gain, -shard) max so tie-breaking matches the sequential
    scan order.
    """
    fn_backend = splits.NUMERIC_BACKENDS[backend]

    def make(Lp, impurity, task, min_records):
        def local(sv, si, leaf_of, w, stats, cl):
            # sv/si: (m_local, n_local) slices of the presorted order.
            def per_col(v, s, c):
                lf, ww, st = leaf_of[s], w[s], stats[s]
                inbag = (ww > 0) & (lf > 0)
                contrib = jnp.where(inbag[:, None], st, 0.0)
                loc_tot = jax.ops.segment_sum(contrib, lf, num_segments=Lp + 1)
                loc_last = jax.ops.segment_max(
                    jnp.where(inbag, v, -jnp.inf), lf, num_segments=Lp + 1)
                all_tot = jax.lax.all_gather(loc_tot, row_axis)      # (W, L+1, S)
                all_last = jax.lax.all_gather(loc_last, row_axis)    # (W, L+1)
                r = jax.lax.axis_index(row_axis)
                W = all_tot.shape[0]
                before = (jnp.arange(W) < r)[:, None, None]
                h_init = jnp.sum(jnp.where(before, all_tot, 0.0), axis=0)
                totals = jnp.sum(all_tot, axis=0)
                v_init = jnp.max(jnp.where(before[..., 0], all_last, -jnp.inf), axis=0)
                v_init = jnp.where(jnp.isfinite(v_init), v_init, jnp.inf)  # "none" sentinel
                g, t = fn_backend(v, lf, ww, st, c, Lp, impurity, task,
                                  min_records, h_init=h_init, v_init=v_init,
                                  totals=totals)
                # merge over row shards: max gain, ties -> earliest shard
                key = jnp.where(jnp.isfinite(g), g, -jnp.inf)
                allg = jax.lax.all_gather(key, row_axis)             # (W, L+1)
                allt = jax.lax.all_gather(t, row_axis)
                win = jnp.argmax(allg, axis=0)  # first max = earliest shard (scan order)
                gsel = jnp.take_along_axis(allg, win[None], 0)[0]
                tsel = jnp.take_along_axis(allt, win[None], 0)[0]
                return gsel, tsel

            return jax.vmap(per_col)(sv, si, cl)

        return local

    def fn(sorted_vals, sorted_idx, leaf_of, w, stats, cand, Lp,
           impurity, task, min_records):
        local = make(Lp, impurity, task, min_records)
        sharded = _shmap(
            local, mesh,
            in_specs=(P(feature_axis, row_axis), P(feature_axis, row_axis),
                      P(None), P(None), P(None, None), P(feature_axis, None)),
            out_specs=(P(feature_axis, None), P(feature_axis, None)))
        return sharded(sorted_vals, sorted_idx, leaf_of, w, stats, cand)

    return fn


# ---------------------------------------------------------------------------
# Histogram (PLANET-style) supersplit: psum of (bins × stats) tables
# ---------------------------------------------------------------------------

def make_hist_sharded_supersplit(mesh, feature_axis: str = "model",
                                 row_axis: Optional[str] = "data"):
    """Approximate supersplit_fn for `split_mode="hist"` (DESIGN.md §6).

    Columns are sharded over `feature_axis` (the paper's splitter layout);
    ROWS — plain row order, no presorted state — are sharded over `row_axis`
    together with the class list / bag weights / stats.  Each shard
    scatter-adds its local per-leaf (bin × stat) count table and a single
    `psum` over `row_axis` merges them: (L+1)·B·S floats per column per
    level, independent of n.

    This is the paper's network-complexity contrast made executable: the
    PLANET-style histogram merge is a fixed-size reduction of count tables,
    whereas the exact 2-D supersplit (make_2d_sharded_supersplit) must
    all_gather per-shard scan state (prefix histograms + last-seen values
    + per-shard bests) so every row shard can resume the EXACT pass where
    its predecessor stopped.  The price of the cheap merge is that only
    `num_bins` thresholds per column are ever considered.

    `row_axis=None` gives the column-sharded-only variant (rows replicated,
    no psum).  Returns fn(bin_of, bin_edges, leaf_of, w, stats, cand, Lp,
    impurity, task, min_records) -> (gains, thresholds), each (m, L+1) —
    the hist-mode supersplit_fn signature of `tree._level_step_core`.  The
    bucket count is read off bin_edges (shape (m, num_bins)), so the fn
    always agrees with the TreeParams that produced the bucket state.
    """

    def fn(bin_of, bin_edges, leaf_of, w, stats, cand, Lp,
           impurity, task, min_records):
        def local(bo, be, cl, lf, ww, st):
            def per_col(b, e, c):
                table = splits.categorical_count_table(
                    b, lf, ww, st, Lp, e.shape[0])
                if row_axis is not None:
                    table = jax.lax.psum(table, row_axis)    # the merge
                return splits.best_numeric_split_histogram(
                    table, e, c, impurity, task, min_records)
            return jax.vmap(per_col)(bo, be, cl)

        sharded = _shmap(
            local, mesh,
            in_specs=(P(feature_axis, row_axis), P(feature_axis, None),
                      P(feature_axis, None), P(row_axis), P(row_axis),
                      P(row_axis, None)),
            out_specs=(P(feature_axis, None), P(feature_axis, None)))
        return sharded(bin_of, bin_edges, cand, leaf_of, w, stats)

    return fn


# ---------------------------------------------------------------------------
# 1-bit condition broadcast (Alg. 2 steps 5/7) under the mesh
# ---------------------------------------------------------------------------

def make_sharded_evaluate(mesh, feature_axis: str = "model"):
    """Winning-condition evaluation: the owner of the winning column computes
    the bit; a psum over the splitter axis broadcasts it (n bits per level —
    the paper's Table 1 network row for DRF)."""

    def fn(num_cols, leaf_of, feat_of_leaf, thr_of_leaf, m_num):
        # num_cols: (m_num, n) raw columns sharded over feature_axis.
        def local(cols, leaf_of, feat_of_leaf, thr_of_leaf):
            k = jax.lax.axis_index(feature_axis)
            mloc = cols.shape[0]
            lo = k * mloc
            f = feat_of_leaf[leaf_of]                       # global feature id
            mine = (f >= lo) & (f < lo + mloc)
            jloc = jnp.clip(f - lo, 0, mloc - 1)
            x = cols[jloc, jnp.arange(cols.shape[1])]
            bit = mine & (x <= thr_of_leaf[leaf_of])
            return jax.lax.psum(bit.astype(jnp.uint8), feature_axis)

        sharded = _shmap(
            local, mesh,
            in_specs=(P(feature_axis, None), P(None), P(None), P(None)),
            out_specs=P(None))
        return sharded(num_cols, leaf_of, feat_of_leaf, thr_of_leaf) > 0

    return fn


# ---------------------------------------------------------------------------
# One DRF level as a single jittable step (the dry-run / roofline workload)
# ---------------------------------------------------------------------------

def drf_level_step_fn(mesh, *, num_leaves: int, num_classes: int,
                      impurity: str = "gini", backend: str = "segment",
                      feature_axis: str = "model", row_axis: str = "data"):
    """Build the jittable 'one depth level of DRF' step used by launch/dryrun.

    Inputs (see launch/specs): sorted_vals/sorted_idx (m, n) sharded
    (feature_axis, row_axis); leaf_of (n,), labels (n,), w (n,) sharded
    (row_axis,).  Output: per-(feature, leaf) best gains/thresholds plus the
    winning per-leaf split — i.e. Alg. 2 step 3 for one level, end to end.
    """
    sup = make_2d_sharded_supersplit(mesh, feature_axis, row_axis, backend)

    def step(sorted_vals, sorted_idx, leaf_of, labels, w, cand):
        stats = splits.row_stats(labels, w, num_classes, "classification")
        gains, thr = sup(sorted_vals, sorted_idx, leaf_of, w, stats, cand,
                         num_leaves, impurity, "classification", 1.0)
        best_feat = jnp.argmax(gains, axis=0)               # (L+1,)
        best_gain = jnp.max(gains, axis=0)
        best_thr = jnp.take_along_axis(thr, best_feat[None], 0)[0]
        return best_feat.astype(jnp.int32), best_gain, best_thr

    return step
