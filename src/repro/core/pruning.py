"""Sprint-style record pruning (paper §3): device-resident row compaction.

When the fraction of rows sitting in CLOSED leaves reaches
`TreeParams.prune_closed_frac`, the drivers drop (a subset of) those rows
and filter every row-indexed array — the presorted order is FILTERED, not
re-sorted (stability preserves it), so the one-time cost is one pass, the
trade-off rule the paper describes.  Dropping any subset of closed rows
is result-invariant (closed rows never contribute to a split again), which
buys two generalizations over the seed implementation:

  * mesh engines: the drop count is rounded DOWN to the engine's row-shard
    width (`plan_drop`), so n stays shard_map-divisible;
  * the batched builder: only rows closed in EVERY tree of the batch are
    dropped (each is inside every tree's closed set, so each per-tree
    leaf-ordered prefix structure survives the filter).
"""
from __future__ import annotations

import jax.numpy as jnp


def plan_drop(n: int, closed: int, row_shards: int, frac: float) -> int:
    """How many closed rows to drop (0 = don't prune this level)."""
    if n <= 0 or closed <= 0 or closed / n < frac:
        return 0
    drop = closed - closed % row_shards
    return drop if 0 < drop < n else 0


def keep_mask(closed_mask: jnp.ndarray, drop: int) -> jnp.ndarray:
    """Keep everything except the first `drop` closed rows (row order)."""
    csum = jnp.cumsum(closed_mask.astype(jnp.int32))
    return (~closed_mask) | (csum > drop)


def compact_rows(*, keep, drop, leaf_of, ord_idx, sorted_vals, sorted_idx,
                 bin_of, num, cat, stats, w, labels, use_ord, hist, m_num):
    """Filter every row-indexed array down to the kept rows.

    Handles both driver layouts: per-tree (`leaf_of` (n,), `ord_idx`
    (m, n), `stats` (n, S)) and batched (`leaf_of` (T, n), `ord_idx`
    (T, m, n), `stats` (T, n, S)).  Under the leaf-ordered layout every
    dropped row sits in each tree's contiguous leaf-0 prefix, so filtering
    each (tree, column) order keeps it (leaf, value)-sorted; the
    permutation lands in ONE flat nonzero/gather over all T·m columns.
    Returns the updated (n, leaf_of, ord_idx, sorted_vals, sorted_idx,
    bin_of, num, cat, stats, w, labels).
    """
    batched = leaf_of.ndim == 2
    n = leaf_of.shape[-1]
    n_new = n - drop
    remap = jnp.cumsum(keep.astype(jnp.int32)) - 1
    keep_idx = jnp.nonzero(keep, size=n_new)[0]
    if use_ord:
        oi = ord_idx if batched else ord_idx[None]
        T = oi.shape[0]
        sel = jnp.take(keep, oi)                       # (T, m, n)
        flat = jnp.nonzero(sel.reshape(-1), size=T * m_num * n_new)[0]
        oi = jnp.take(remap, oi.reshape(-1)[flat]).reshape(T, m_num, n_new)
        ord_idx = oi if batched else oi[0]
    elif hist:
        # bucket ids are row-indexed; no sorted state to filter
        if m_num:
            bin_of = bin_of[:, keep_idx]
    elif m_num and sorted_vals.size:
        # filter the presorted order (stability preserves it): every column
        # keeps the same n_new rows, so the flat row-major nonzero is
        # (m_num, n_new) column blocks
        kept_cols = jnp.take(keep, sorted_idx)
        flat = jnp.nonzero(kept_cols.reshape(-1), size=m_num * n_new)[0]
        sorted_idx = jnp.take(remap,
                              sorted_idx.reshape(-1)[flat]).reshape(
            m_num, n_new)
        sorted_vals = sorted_vals.reshape(-1)[flat].reshape(m_num, n_new)
    num = num[keep_idx]
    cat = cat[keep_idx]
    labels = labels[keep_idx]
    if batched:
        stats = stats[:, keep_idx]
        w = w[:, keep_idx]
        leaf_of = leaf_of[:, keep_idx]
    else:
        stats = stats[keep_idx]
        w = w[keep_idx]
        leaf_of = leaf_of[keep_idx]
    return (n_new, leaf_of, ord_idx, sorted_vals, sorted_idx, bin_of, num,
            cat, stats, w, labels)
