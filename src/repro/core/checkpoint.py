"""Level-granular checkpoint/resume for streamed training (DESIGN.md §9).

The streamed driver (`tree.build_forest_streamed`) is uniquely cheap to
checkpoint: between depth levels ALL of its n-sized training state is
already host-resident numpy — the (T, n_act) leaf ids, the flat-tree
accumulators, the finalized level's split decisions, and the pruning
row map.  Bag weights and PRNG keys need no snapshot at all because
every random draw is a pure function of (seed, tree index) (paper
§2.2); the resume path re-derives them bit-exactly.  A snapshot is
therefore a single uncompressed .npz per tree batch, written atomically
(tmp + `os.replace`, `repro.core.atomicio`), and resuming from it
replays the remaining levels through the exact same jitted programs —
node-for-node identical to the uninterrupted fit, which
tests/test_faults.py asserts under SIGKILL.

Layout of a checkpoint directory (one per forest fit):

    manifest.json          fingerprints (source / params / seed) +
                           the set of COMPLETED tree batches
    trees_<lo>-<hi>.npz    finished trees of a completed batch
    snap_<lo>-<hi>.npz     level snapshot of the in-flight batch
                           (deleted once its batch completes)

`manifest.json` is the commit record: a batch exists only once the
manifest says so, and the trees file is written (atomically) BEFORE
the manifest update, so a kill between the two merely retrains that
batch.  Resuming against the wrong cache/params/seed raises
`CheckpointMismatchError` before any state is touched.

Under multi-host sharding only process 0 writes (`jax.process_index()`)
while every host fingerprint-checks the manifest it can read — the
snapshot holds replicated host state, so one copy is enough.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core import atomicio

FORMAT_VERSION = 1

# Wall-clock seconds spent inside checkpoint writes (snapshots, trees,
# manifests).  benchmarks/outofcore_bench.py reads the delta around a
# checkpointed fit to gate the overhead fraction (<= 5%).
CKPT_WALL = [0.0]

# Test hook (repro.testing.faults): called after each level snapshot
# lands on disk, with (depth, path) — armed to SIGKILL at a chosen
# level for the kill-and-resume parity tests.
POST_SNAPSHOT_HOOK: list = [None]


class CheckpointError(RuntimeError):
    """A checkpoint directory is unusable (corrupt / wrong version)."""


class CheckpointMismatchError(CheckpointError):
    """Resume state does not match the fit (source / params / seed)."""


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------

def source_fingerprint(source) -> dict:
    """Identity of a `dataset.RowSource` for resume validation.

    Covers everything a streamed fit reads from the source that shapes
    the trees: row/column counts, the bucket budget, the task/classes,
    and a content hash of the decoded edges (two caches quantized from
    different data share none of these by accident)."""
    edges = np.ascontiguousarray(source.edges, np.float32)
    return {
        "n": int(source.n),
        "m_num": int(source.m_num),
        "num_bins": int(source.num_bins),
        "num_classes": int(source.num_classes),
        "task": str(source.task),
        "edges_sha256": hashlib.sha256(edges.tobytes()).hexdigest(),
    }


def params_fingerprint(params) -> dict:
    """`TreeParams` as a jsonable dict (every field shapes the trees)."""
    return {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else repr(v))
            for k, v in dataclasses.asdict(params).items()}


def _process_index() -> int:
    import jax
    return int(jax.process_index())


# ---------------------------------------------------------------------------
# _NodeAccum (flat-tree accumulator) serialization
# ---------------------------------------------------------------------------

def _pack_acc(acc, open_nodes) -> dict:
    """Flatten one `tree._NodeAccum` + its open-node ids to numpy arrays.

    Streamed training is numeric-only, so `is_cat` is all-False and
    `cat_mask` all-None by construction — asserted here rather than
    serialized.  Exact-width dtypes (float64 for thresholds/gains that
    live as Python floats) make the round trip bit-lossless."""
    assert not any(acc.is_cat), "streamed accumulators are numeric-only"
    assert all(cm is None for cm in acc.cat_mask)
    n_nodes = len(acc.feature)
    value = (np.stack(acc.value).astype(np.float32) if n_nodes
             else np.zeros((0, acc._C), np.float32))
    return {
        "feature": np.asarray(acc.feature, np.int64),
        "threshold": np.asarray(acc.threshold, np.float64),
        "children": (np.asarray(acc.children, np.int64).reshape(n_nodes, 2)
                     if n_nodes else np.zeros((0, 2), np.int64)),
        "value": value,
        "n_node": np.asarray(acc.n_node, np.float64),
        "gain": np.asarray(acc.gain, np.float64),
        "depth": np.asarray(acc.depth, np.int64),
        "open": np.asarray(open_nodes, np.int64),
    }


def _unpack_acc(arrs: dict, num_classes: int, task: str):
    """Rebuild (`_NodeAccum`, open_nodes) from `_pack_acc` arrays."""
    from repro.core.tree import _NodeAccum
    acc = _NodeAccum(num_classes, task)
    n_nodes = len(arrs["feature"])
    acc.feature = [int(x) for x in arrs["feature"]]
    acc.threshold = [float(x) for x in arrs["threshold"]]
    acc.is_cat = [False] * n_nodes
    acc.cat_mask = [None] * n_nodes
    acc.children = [[int(a), int(b)] for a, b in arrs["children"]]
    acc.value = [np.ascontiguousarray(row) for row in
                 np.asarray(arrs["value"], np.float32)]
    acc.n_node = [float(x) for x in arrs["n_node"]]
    acc.gain = [float(x) for x in arrs["gain"]]
    acc.depth = [int(x) for x in arrs["depth"]]
    return acc, [int(x) for x in arrs["open"]]


# ---------------------------------------------------------------------------
# Finished-tree serialization (per completed batch)
# ---------------------------------------------------------------------------

_TREE_FIELDS = ("feature", "threshold", "is_cat", "cat_mask", "children",
                "value", "n_node", "gain", "depth")


def pack_stats(stats_logs) -> np.ndarray:
    """`LevelStats` logs as one json scalar array (npz-embeddable)."""
    return np.array(json.dumps(
        [[dataclasses.asdict(s) for s in log] for log in stats_logs]))


def unpack_stats(arr) -> list:
    from repro.core.tree import LevelStats
    return [[LevelStats(**d) for d in log] for log in json.loads(str(arr))]


def _pack_trees(trees, stats_logs) -> dict:
    out = {"format_version": np.int32(FORMAT_VERSION),
           "num_trees": np.int32(len(trees)),
           "m_num": np.int32(trees[0].m_num),
           "task": np.array(trees[0].task)}
    for i, tr in enumerate(trees):
        for f in _TREE_FIELDS:
            out[f"t{i}_{f}"] = np.asarray(getattr(tr, f))
    out["stats_json"] = pack_stats(stats_logs)
    return out


def _unpack_trees(z) -> tuple[list, list]:
    from repro.core.tree import Tree
    m_num, task = int(z["m_num"]), str(z["task"])
    trees = [Tree(m_num=m_num, task=task,
                  **{f: np.asarray(z[f"t{i}_{f}"]) for f in _TREE_FIELDS})
             for i in range(int(z["num_trees"]))]
    return trees, unpack_stats(z["stats_json"])


def _save_npz(path: str, arrays: dict) -> None:
    # uncompressed on purpose: checkpoints are transient (deleted at batch
    # commit) and written on the fit's critical path — zlib costs ~9x the
    # raw write and buys nothing we keep
    t0 = time.perf_counter()
    atomicio.atomic_replace(
        path, lambda tmp: np.savez(open(tmp, "wb"), **arrays))
    CKPT_WALL[0] += time.perf_counter() - t0


def _shrink_ids(a: np.ndarray) -> np.ndarray:
    """Smallest exact unsigned dtype for a non-negative id array — the
    (T, n_act) leaf ids and the row map are the only n-sized payloads in
    a snapshot, and their value ranges are tiny compared to their storage
    dtype (uint8 covers leaf ids to depth 7, uint32 any practical n)."""
    hi = int(a.max()) if a.size else 0
    for dt in (np.uint8, np.uint16, np.uint32):
        if hi <= np.iinfo(dt).max:
            return a.astype(dt)
    return np.ascontiguousarray(a)


# ---------------------------------------------------------------------------
# Streamed-driver level snapshots
# ---------------------------------------------------------------------------
#
# Captured at the END of a level iteration in `build_forest_streamed`,
# after the level's bookkeeping and Sprint pruning: the (T, n_act) leaf
# ids, the pruning row map, the frontier sizes, the level's finalized
# split decisions (the `dec` tuple the NEXT level's chunk pass replays),
# and the flat-tree accumulators.  Labels and bag weights are NOT stored
# — both are re-derived on resume (labels from the source, weights from
# the seeded bagging) and compacted by the stored row map, bit-exactly.

def pack_stream_state(*, tidx, depth, Ls, leaf_np, active, dec, Lpp,
                      accs, open_nodes, stats_logs) -> dict:
    state = {
        "format_version": np.int32(FORMAT_VERSION),
        "tidx": np.asarray([int(t) for t in tidx], np.int64),
        "next_depth": np.int64(depth + 1),
        "Lpp": np.int64(Lpp),
        "Ls": np.asarray(Ls, np.int64),
        "leaf": _shrink_ids(np.ascontiguousarray(leaf_np)),
        "dec_feat": np.asarray(dec[0]),
        "dec_thr": np.asarray(dec[1]),
        "dec_left": np.asarray(dec[2]),
        "dec_right": np.asarray(dec[3]),
        "stats_json": pack_stats(stats_logs),
    }
    if active is not None:
        state["active"] = _shrink_ids(np.asarray(active))
    for i, (acc, opn) in enumerate(zip(accs, open_nodes)):
        for k, v in _pack_acc(acc, opn).items():
            state[f"a{i}_{k}"] = v
    return state


def unpack_stream_state(state: dict, *, num_classes: int, task: str) -> dict:
    T = len(state["tidx"])
    accs, open_nodes = [], []
    for i in range(T):
        pre = f"a{i}_"
        acc, opn = _unpack_acc(
            {k[len(pre):]: v for k, v in state.items()
             if k.startswith(pre)}, num_classes, task)
        accs.append(acc)
        open_nodes.append(opn)
    return {
        "next_depth": int(state["next_depth"]),
        "Lpp": int(state["Lpp"]),
        "Ls": [int(x) for x in state["Ls"]],
        "leaf": np.ascontiguousarray(state["leaf"], np.int32),
        "active": (np.ascontiguousarray(state["active"], np.int64)
                   if "active" in state else None),
        "dec": (state["dec_feat"], state["dec_thr"],
                state["dec_left"], state["dec_right"]),
        "accs": accs,
        "open_nodes": open_nodes,
        "stats_logs": unpack_stats(state["stats_json"]),
    }


# ---------------------------------------------------------------------------
# The checkpointer
# ---------------------------------------------------------------------------

class StreamCheckpointer:
    """Manages one checkpoint directory across a streamed forest fit.

    `prepare` validates (or initializes) the manifest; per tree batch
    the driver calls `save_snapshot` after each completed level,
    `flush` before escalating a read failure, and `finish_batch` when
    the batch's trees are done; `load_batch`/`load_snapshot` feed the
    resume path.  All writes are atomic and happen only on process 0.
    """

    def __init__(self, directory, *, every: int = 1):
        self.dir = os.fspath(directory)
        self.every = max(1, int(every))
        self.is_writer = _process_index() == 0
        self._manifest: Optional[dict] = None
        self._pending: Optional[tuple] = None   # (key, depth, state)

    # -- paths ----------------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    @staticmethod
    def batch_key(tidx) -> str:
        tidx = [int(t) for t in tidx]
        return f"{tidx[0]}-{tidx[-1]}"

    def _trees_path(self, key: str) -> str:
        return os.path.join(self.dir, f"trees_{key}.npz")

    def _snap_path(self, key: str) -> str:
        return os.path.join(self.dir, f"snap_{key}.npz")

    # -- lifecycle ------------------------------------------------------
    def prepare(self, *, source, params, seed: int, resume: bool) -> None:
        """Fingerprint-check an existing manifest or initialize a fresh one.

        `resume=True` against a populated directory validates that the
        source/params/seed match what the checkpoints were written for
        (`CheckpointMismatchError` otherwise); against an empty
        directory it simply starts fresh, so crash-loop supervisors can
        pass `resume=True` unconditionally.  `resume=False` discards
        any prior state."""
        meta = {"source": source_fingerprint(source),
                "params": params_fingerprint(params),
                "seed": int(seed)}
        existing = self._read_manifest()
        if resume and existing is not None:
            if int(existing.get("format_version", -1)) != FORMAT_VERSION:
                raise CheckpointError(
                    f"checkpoint dir {self.dir!r} is format "
                    f"v{existing.get('format_version')}; this build reads "
                    f"v{FORMAT_VERSION} — delete it or train fresh")
            bad = [k for k in meta if existing["meta"].get(k) != meta[k]]
            if bad:
                raise CheckpointMismatchError(
                    f"checkpoint dir {self.dir!r} was written for a "
                    f"different fit (mismatched: {', '.join(bad)}) — "
                    f"resuming would mix trees from two configurations. "
                    f"Point checkpoint_dir at the matching cache/params "
                    f"or pass resume=False to discard it")
            self._manifest = existing
            return
        self._manifest = {"format_version": FORMAT_VERSION, "meta": meta,
                          "batches": {}}
        if self.is_writer:
            os.makedirs(self.dir, exist_ok=True)
            for f in os.listdir(self.dir):   # drop stale batch artifacts
                if f.startswith(("trees_", "snap_")) and f.endswith(".npz"):
                    os.unlink(os.path.join(self.dir, f))
            self._write_manifest()

    def _read_manifest(self) -> Optional[dict]:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointError(
                f"unreadable checkpoint manifest "
                f"{self._manifest_path()!r}: {e}") from e

    def _write_manifest(self) -> None:
        if not self.is_writer:
            return
        t0 = time.perf_counter()
        atomicio.atomic_write_json(self._manifest_path(), self._manifest)
        CKPT_WALL[0] += time.perf_counter() - t0

    # -- completed batches ---------------------------------------------
    def load_batch(self, tidx) -> Optional[tuple[list, list]]:
        """(trees, stats) of a COMPLETED batch, or None if not finished."""
        key = self.batch_key(tidx)
        entry = self._manifest["batches"].get(key)
        if entry is None:
            return None
        if entry["tree_indices"] != [int(t) for t in tidx]:
            raise CheckpointMismatchError(
                f"checkpoint batch {key!r} holds trees "
                f"{entry['tree_indices']} but the fit asked for "
                f"{[int(t) for t in tidx]} — tree_batch changed between "
                f"runs; resume with the original batch size")
        path = self._trees_path(key)
        try:
            with np.load(path, allow_pickle=False) as z:
                return _unpack_trees(z)
        except (OSError, ValueError, KeyError) as e:
            raise CheckpointError(
                f"manifest lists completed batch {key!r} but its trees "
                f"file {path!r} is missing or unreadable ({e}) — the "
                f"directory was tampered with; delete it and retrain"
            ) from e

    def finish_batch(self, tidx, trees, stats_logs) -> None:
        """Commit a finished batch: trees file, then manifest, then drop
        the level snapshot.  Ordered so a kill between any two steps
        loses at most this batch's recompute."""
        key = self.batch_key(tidx)
        self._pending = None
        if not self.is_writer:
            return
        _save_npz(self._trees_path(key), _pack_trees(trees, stats_logs))
        self._manifest["batches"][key] = {
            "tree_indices": [int(t) for t in tidx]}
        self._write_manifest()
        snap = self._snap_path(key)
        if os.path.exists(snap):
            os.unlink(snap)

    # -- level snapshots ------------------------------------------------
    def save_snapshot(self, tidx, depth: int, state: dict) -> None:
        """Record level `depth`'s end-of-level state; write it to disk
        on the `checkpoint_every` cadence (the latest state is always
        held pending so `flush` can persist it on failure)."""
        key = self.batch_key(tidx)
        self._pending = (key, depth, state)
        if (depth + 1) % self.every == 0:
            self.flush()

    def flush(self) -> None:
        """Write the held snapshot now (no-op when already on disk)."""
        if self._pending is None or not self.is_writer:
            return
        key, depth, state = self._pending
        self._pending = None
        path = self._snap_path(key)
        _save_npz(path, state)
        if POST_SNAPSHOT_HOOK[0] is not None:
            POST_SNAPSHOT_HOOK[0](depth, path)

    def load_snapshot(self, tidx) -> Optional[dict]:
        """The in-flight batch's level snapshot as a dict of arrays, or
        None (start the batch from depth 0)."""
        path = self._snap_path(self.batch_key(tidx))
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                state = {k: np.asarray(v) for k, v in z.items()}
        except (OSError, ValueError) as e:
            raise CheckpointError(
                f"unreadable level snapshot {path!r}: {e} — it was "
                f"written atomically, so this is external corruption; "
                f"delete the file to retrain the batch from scratch"
            ) from e
        if int(state["format_version"]) != FORMAT_VERSION:
            raise CheckpointError(
                f"level snapshot {path!r} is format "
                f"v{int(state['format_version'])}; this build reads "
                f"v{FORMAT_VERSION}")
        if list(state["tidx"]) != [int(t) for t in tidx]:
            raise CheckpointMismatchError(
                f"level snapshot {path!r} holds trees "
                f"{list(state['tidx'])}, not {[int(t) for t in tidx]}")
        return state
