"""Serving: the forest inference server + the LM prefill/decode engine.

`ForestServer` is the ROADMAP "serving export path" wire-up: a long-lived
process loads ONE versioned `PackedForest` .npz (`forest.PackedForest.save`)
and serves `predict` off the stacked arrays — the jitted whole-forest
descent is compiled ONCE at `load` time by a warm-up call, so the first
real request pays no trace.  `benchmarks/run.py serve` records the p50
single-row latency of exactly this path.

The LM half (prefill + decode steps and a batched request engine) keeps
two KV-cache sharding recipes (DESIGN.md §5):
  * "batch"  — batch over "data", kv-heads over "model" (decode_32k, B=128)
  * "seq"    — cache sequence over "data" (flash-decoding-style partial
               softmax combine left to XLA SPMD), heads over "model"
               (long_500k, B=1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.train import sharding as shd


# ---------------------------------------------------------------------------
# Forest serving (ROADMAP "Serving export path" follow-up)
# ---------------------------------------------------------------------------

class InvalidRequest(ValueError):
    """A malformed predict request (DESIGN.md §9 graceful degradation).

    Raised by `ForestServer.predict` BEFORE the jitted descent for
    wrong-shape inputs, non-finite numeric rows, or categorical ids
    outside the declared arity — the cases that would otherwise either
    crash out of the serving loop or silently route every row down a
    garbage path.  The server holds no per-request state, so catching
    this and answering the client with an error leaves it serving."""

@dataclasses.dataclass
class ForestServer:
    """Low-latency inference server over an exported `PackedForest`.

    Usage:
        srv = ForestServer.load("model.npz")    # load + warm the jit
        probs = srv.predict(num_row, cat_row)   # (B, C), no first-call jit

    `load` deserializes the versioned .npz (no pickle, no training code)
    and immediately runs one dummy batch through `predict_proba` per
    common batch size so the descent program is compiled before traffic
    arrives.  Single-row latency is the serving-critical number
    (`benchmarks/run.py serve` measures its p50 on this exact class).
    """

    packed: object                      # forest.PackedForest
    m_cat: int = 0
    arities: Optional[tuple] = None     # per categorical column, if known

    @classmethod
    def load(cls, path, m_cat: int = 0,
             warm_batch_sizes=(1,), arities=None) -> "ForestServer":
        """Load an exported forest and pre-compile the descent.

        `m_cat` is the categorical input width requests will carry (the
        .npz stores only the model; 0 for all-numeric forests).
        `warm_batch_sizes` picks which request shapes are traced at
        startup (the descent retraces per batch size — warm every size
        the service will see; 1 covers the single-row latency path).
        `arities` (optional, len m_cat) enables per-column range checks
        on categorical ids: an out-of-arity id raises `InvalidRequest`
        instead of indexing the split mask at a wrong row.
        """
        from repro.core.forest import PackedForest
        packed = PackedForest.load(path)
        if arities is not None:
            arities = tuple(int(a) for a in arities)
            if len(arities) != int(m_cat):
                raise ValueError(
                    f"arities has {len(arities)} entries but m_cat="
                    f"{int(m_cat)} — pass one arity per categorical "
                    f"column")
        srv = cls(packed=packed, m_cat=int(m_cat), arities=arities)
        if srv._needs_cat() and srv.m_cat == 0:
            raise ValueError(
                "this forest splits on categorical features but the "
                "server was loaded with m_cat=0 — pass the dataset's "
                "categorical column count to ForestServer.load(path, "
                "m_cat=...) so requests carry the categorical row")
        for b in warm_batch_sizes:
            num = jnp.zeros((b, packed.m_num), jnp.float32)
            cat = jnp.zeros((b, srv.m_cat), jnp.int32)
            jax.block_until_ready(packed.predict_proba(num, cat))
        return srv

    def _needs_cat(self) -> bool:
        return bool(np.asarray(self.packed.is_cat).any())

    def _validate(self, num: np.ndarray, cat) -> np.ndarray:
        """Reject malformed requests with `InvalidRequest` (typed, safe
        to catch-and-answer) before anything reaches the device."""
        if num.ndim != 2 or num.shape[1] != self.packed.m_num:
            raise InvalidRequest(
                f"numeric input must be (B, {self.packed.m_num}), got "
                f"shape {tuple(num.shape)}")
        if num.size and not np.isfinite(num).all():
            bad = np.argwhere(~np.isfinite(num))[0]
            raise InvalidRequest(
                f"numeric input contains a non-finite value at row "
                f"{int(bad[0])}, column {int(bad[1])} — NaN/inf would "
                f"route every comparison to the right child silently")
        if cat is None:
            if self.m_cat:
                raise InvalidRequest(
                    f"this server was loaded with m_cat={self.m_cat}: "
                    "every request must carry a (B, m_cat) categorical "
                    "array (an empty one would silently route every "
                    "categorical split by category 0)")
            return np.zeros((num.shape[0], 0), np.int32)
        cat = np.asarray(cat)
        if not np.issubdtype(cat.dtype, np.integer):
            raise InvalidRequest(
                f"categorical input must be integer ids, got dtype "
                f"{cat.dtype}")
        if cat.ndim != 2 or cat.shape[1] != self.m_cat:
            raise InvalidRequest(
                f"categorical input must be (B, {self.m_cat}), got "
                f"shape {tuple(cat.shape)}")
        if cat.shape != (num.shape[0], self.m_cat):
            raise InvalidRequest(
                f"categorical batch {cat.shape[0]} != numeric batch "
                f"{num.shape[0]}")
        if cat.size:
            if cat.min() < 0:
                raise InvalidRequest("categorical ids must be >= 0")
            if self.arities is not None:
                hi = cat.max(axis=0)
                for j, a in enumerate(self.arities):
                    if int(hi[j]) >= a:
                        raise InvalidRequest(
                            f"categorical column {j} has id "
                            f"{int(hi[j])} but arity {a} (valid ids "
                            f"0..{a - 1})")
        return cat.astype(np.int32, copy=False)

    def predict(self, num, cat=None):
        """(B, C) forest-mean distributions; ONE jitted call.

        Malformed requests raise `InvalidRequest` before the descent —
        the caller answers the client and keeps serving (no state to
        recover; see tests/test_server_robust.py)."""
        num = np.asarray(num, np.float32)
        cat = self._validate(num, cat)
        return self.packed.predict_proba(jnp.asarray(num),
                                         jnp.asarray(cat, jnp.int32))


def prefill_step(params, inputs, cfg, unroll: bool = False):
    """Full-sequence forward; returns (last-position logits, layer caches).

    Only the final position's logits are projected — materializing the full
    (B, S, vocab) tensor at 32k prefill would be pure waste (the sampler
    consumes one position).
    """
    x, _, caches = transformer.forward_hidden(params, inputs, cfg,
                                              collect_cache=True,
                                              unroll=unroll)
    logits = transformer.project_logits(params, x[:, -1:], cfg)
    return logits, caches


def decode_step(params, caches, inputs, cache_len, cfg, unroll: bool = False):
    """One new token against a max_seq cache (the dry-run decode workload)."""
    return transformer.decode_step(params, caches, inputs, cache_len, cfg,
                                   unroll=unroll)


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)


@dataclasses.dataclass
class BatchedServer:
    """Minimal batched continuous-decode server for the examples.

    Holds a fixed-size batch of slots; each slot has a cache position.  New
    requests prefill into a free slot; every `step()` decodes one token for
    all active slots.
    """
    cfg: object
    params: object
    max_seq: int
    batch: int

    def __post_init__(self):
        self.caches = transformer.init_cache(self.cfg, self.batch, self.max_seq)
        self.lens = jnp.zeros((self.batch,), jnp.int32)
        self.active = [False] * self.batch
        self.outputs: list[list[int]] = [[] for _ in range(self.batch)]
        self._decode = jax.jit(
            lambda p, c, t, l: transformer.decode_step(p, c, t, l, self.cfg))

    def add_request(self, prompt_tokens) -> int:
        slot = self.active.index(False)
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        # sequential prefill through decode steps (simple, exercises the
        # same path; bulk prefill_step is used by examples/serve_lm.py)
        for t in toks:
            tok = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(t)
            _, self.caches = self._decode(self.params, self.caches, tok, self.lens)
            self.lens = self.lens.at[slot].add(1)
        self.active[slot] = True
        return slot

    def step(self) -> dict[int, int]:
        """Decode one token for every active slot; returns {slot: token}."""
        last = jnp.asarray(
            [self.outputs[i][-1] if self.outputs[i] else 0
             for i in range(self.batch)], jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, self.caches, last, self.lens)
        nxt = jnp.argmax(logits[:, 0], axis=-1)
        out = {}
        for i in range(self.batch):
            if self.active[i]:
                tok = int(nxt[i])
                self.outputs[i].append(tok)
                self.lens = self.lens.at[i].add(1)
                out[i] = tok
        return out

    def finish(self, slot: int) -> list[int]:
        self.active[slot] = False
        toks, self.outputs[slot] = self.outputs[slot], []
        self.lens = self.lens.at[slot].set(0)
        return toks
