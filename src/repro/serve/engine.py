"""Serving: the forest inference server + the LM prefill/decode engine.

`ForestServer` is the ROADMAP "serving export path" wire-up: a long-lived
process loads ONE versioned `PackedForest` .npz (`forest.PackedForest.save`)
and serves `predict` off the stacked arrays — the jitted whole-forest
descent is compiled ONCE at `load` time by a warm-up call, so the first
real request pays no trace.  `benchmarks/run.py serve` records the p50
single-row latency of exactly this path.

The LM half (prefill + decode steps and a batched request engine) keeps
two KV-cache sharding recipes (DESIGN.md §5):
  * "batch"  — batch over "data", kv-heads over "model" (decode_32k, B=128)
  * "seq"    — cache sequence over "data" (flash-decoding-style partial
               softmax combine left to XLA SPMD), heads over "model"
               (long_500k, B=1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.train import sharding as shd


# ---------------------------------------------------------------------------
# Forest serving (ROADMAP "Serving export path" follow-up)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ForestServer:
    """Low-latency inference server over an exported `PackedForest`.

    Usage:
        srv = ForestServer.load("model.npz")    # load + warm the jit
        probs = srv.predict(num_row, cat_row)   # (B, C), no first-call jit

    `load` deserializes the versioned .npz (no pickle, no training code)
    and immediately runs one dummy batch through `predict_proba` per
    common batch size so the descent program is compiled before traffic
    arrives.  Single-row latency is the serving-critical number
    (`benchmarks/run.py serve` measures its p50 on this exact class).
    """

    packed: object                      # forest.PackedForest
    m_cat: int = 0

    @classmethod
    def load(cls, path, m_cat: int = 0,
             warm_batch_sizes=(1,)) -> "ForestServer":
        """Load an exported forest and pre-compile the descent.

        `m_cat` is the categorical input width requests will carry (the
        .npz stores only the model; 0 for all-numeric forests).
        `warm_batch_sizes` picks which request shapes are traced at
        startup (the descent retraces per batch size — warm every size
        the service will see; 1 covers the single-row latency path).
        """
        from repro.core.forest import PackedForest
        packed = PackedForest.load(path)
        srv = cls(packed=packed, m_cat=int(m_cat))
        if srv._needs_cat() and srv.m_cat == 0:
            raise ValueError(
                "this forest splits on categorical features but the "
                "server was loaded with m_cat=0 — pass the dataset's "
                "categorical column count to ForestServer.load(path, "
                "m_cat=...) so requests carry the categorical row")
        for b in warm_batch_sizes:
            num = jnp.zeros((b, packed.m_num), jnp.float32)
            cat = jnp.zeros((b, srv.m_cat), jnp.int32)
            jax.block_until_ready(packed.predict_proba(num, cat))
        return srv

    def _needs_cat(self) -> bool:
        import numpy as np
        return bool(np.asarray(self.packed.is_cat).any())

    def predict(self, num, cat=None):
        """(B, C) forest-mean distributions; ONE jitted call."""
        num = jnp.asarray(num, jnp.float32)
        if cat is None:
            if self.m_cat:
                raise ValueError(
                    f"this server was loaded with m_cat={self.m_cat}: "
                    "every request must carry a (B, m_cat) categorical "
                    "array (an empty one would silently route every "
                    "categorical split by category 0)")
            cat = jnp.zeros((num.shape[0], 0), jnp.int32)
        return self.packed.predict_proba(num, jnp.asarray(cat, jnp.int32))


def prefill_step(params, inputs, cfg, unroll: bool = False):
    """Full-sequence forward; returns (last-position logits, layer caches).

    Only the final position's logits are projected — materializing the full
    (B, S, vocab) tensor at 32k prefill would be pure waste (the sampler
    consumes one position).
    """
    x, _, caches = transformer.forward_hidden(params, inputs, cfg,
                                              collect_cache=True,
                                              unroll=unroll)
    logits = transformer.project_logits(params, x[:, -1:], cfg)
    return logits, caches


def decode_step(params, caches, inputs, cache_len, cfg, unroll: bool = False):
    """One new token against a max_seq cache (the dry-run decode workload)."""
    return transformer.decode_step(params, caches, inputs, cache_len, cfg,
                                   unroll=unroll)


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)


@dataclasses.dataclass
class BatchedServer:
    """Minimal batched continuous-decode server for the examples.

    Holds a fixed-size batch of slots; each slot has a cache position.  New
    requests prefill into a free slot; every `step()` decodes one token for
    all active slots.
    """
    cfg: object
    params: object
    max_seq: int
    batch: int

    def __post_init__(self):
        self.caches = transformer.init_cache(self.cfg, self.batch, self.max_seq)
        self.lens = jnp.zeros((self.batch,), jnp.int32)
        self.active = [False] * self.batch
        self.outputs: list[list[int]] = [[] for _ in range(self.batch)]
        self._decode = jax.jit(
            lambda p, c, t, l: transformer.decode_step(p, c, t, l, self.cfg))

    def add_request(self, prompt_tokens) -> int:
        slot = self.active.index(False)
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        # sequential prefill through decode steps (simple, exercises the
        # same path; bulk prefill_step is used by examples/serve_lm.py)
        for t in toks:
            tok = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(t)
            _, self.caches = self._decode(self.params, self.caches, tok, self.lens)
            self.lens = self.lens.at[slot].add(1)
        self.active[slot] = True
        return slot

    def step(self) -> dict[int, int]:
        """Decode one token for every active slot; returns {slot: token}."""
        last = jnp.asarray(
            [self.outputs[i][-1] if self.outputs[i] else 0
             for i in range(self.batch)], jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, self.caches, last, self.lens)
        nxt = jnp.argmax(logits[:, 0], axis=-1)
        out = {}
        for i in range(self.batch):
            if self.active[i]:
                tok = int(nxt[i])
                self.outputs[i].append(tok)
                self.lens = self.lens.at[i].add(1)
                out[i] = tok
        return out

    def finish(self, slot: int) -> list[int]:
        self.active[slot] = False
        toks, self.outputs[slot] = self.outputs[slot], []
        self.lens = self.lens.at[slot].set(0)
        return toks
