"""Serving: prefill + decode steps and a batched request engine.

Two KV-cache sharding recipes (DESIGN.md §5):
  * "batch"  — batch over "data", kv-heads over "model" (decode_32k, B=128)
  * "seq"    — cache sequence over "data" (flash-decoding-style partial
               softmax combine left to XLA SPMD), heads over "model"
               (long_500k, B=1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.train import sharding as shd


def prefill_step(params, inputs, cfg, unroll: bool = False):
    """Full-sequence forward; returns (last-position logits, layer caches).

    Only the final position's logits are projected — materializing the full
    (B, S, vocab) tensor at 32k prefill would be pure waste (the sampler
    consumes one position).
    """
    x, _, caches = transformer.forward_hidden(params, inputs, cfg,
                                              collect_cache=True,
                                              unroll=unroll)
    logits = transformer.project_logits(params, x[:, -1:], cfg)
    return logits, caches


def decode_step(params, caches, inputs, cache_len, cfg, unroll: bool = False):
    """One new token against a max_seq cache (the dry-run decode workload)."""
    return transformer.decode_step(params, caches, inputs, cache_len, cfg,
                                   unroll=unroll)


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)


@dataclasses.dataclass
class BatchedServer:
    """Minimal batched continuous-decode server for the examples.

    Holds a fixed-size batch of slots; each slot has a cache position.  New
    requests prefill into a free slot; every `step()` decodes one token for
    all active slots.
    """
    cfg: object
    params: object
    max_seq: int
    batch: int

    def __post_init__(self):
        self.caches = transformer.init_cache(self.cfg, self.batch, self.max_seq)
        self.lens = jnp.zeros((self.batch,), jnp.int32)
        self.active = [False] * self.batch
        self.outputs: list[list[int]] = [[] for _ in range(self.batch)]
        self._decode = jax.jit(
            lambda p, c, t, l: transformer.decode_step(p, c, t, l, self.cfg))

    def add_request(self, prompt_tokens) -> int:
        slot = self.active.index(False)
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        # sequential prefill through decode steps (simple, exercises the
        # same path; bulk prefill_step is used by examples/serve_lm.py)
        for t in toks:
            tok = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(t)
            _, self.caches = self._decode(self.params, self.caches, tok, self.lens)
            self.lens = self.lens.at[slot].add(1)
        self.active[slot] = True
        return slot

    def step(self) -> dict[int, int]:
        """Decode one token for every active slot; returns {slot: token}."""
        last = jnp.asarray(
            [self.outputs[i][-1] if self.outputs[i] else 0
             for i in range(self.batch)], jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, self.caches, last, self.lens)
        nxt = jnp.argmax(logits[:, 0], axis=-1)
        out = {}
        for i in range(self.batch):
            if self.active[i]:
                tok = int(nxt[i])
                self.outputs[i].append(tok)
                self.lens = self.lens.at[i].add(1)
                out[i] = tok
        return out

    def finish(self, slot: int) -> list[int]:
        self.active[slot] = False
        toks, self.outputs[slot] = self.outputs[slot], []
        self.lens = self.lens.at[slot].set(0)
        return toks
