"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations with LOGICAL axis names via `shard()`;
the launcher installs a mesh + logical→mesh rules.  Off-mesh (CPU smoke
tests) `shard()` is the identity, so the same model code runs everywhere.

Default rules for the production mesh (DESIGN.md §5):

  batch      -> ("pod", "data")   # data parallel (pod axis = DP across pods)
  seq        -> None              # activations keep seq local ...
  cache_seq  -> "data" only in the long-context decode recipe
  heads / kv_heads / ff / experts / vocab -> "model" (tensor/expert parallel)
  embed_fsdp -> "data"            # parameter FSDP shard dim

A dim keeps its constraint only when divisible by the mesh axis size
(musicgen's 24 heads on a 16-wide model axis simply stay unsharded — the
flattened h·hd weight dim still shards evenly).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "res_seq": None,   # residual-stream seq (Megatron-SP shards it over "model")
    "cache_seq": None,
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "ff": "model",
    # expert parallelism over "data" (all_to_all routing), tensor parallelism
    # WITHIN each expert over "model" — experts and ff must not share an axis
    "experts": "data",
    "expert_cap": None,
    "vocab": "model",
    "embed": None,
    "embed_fsdp": "data",
    "d_inner": "model",
    "state": None,
}

# Decode recipes.  decode_32k: batch over "data", KV-cache seq over "model"
# (kv_heads rarely divide the model axis — 8 kv heads on a 16-wide axis —
# so the cache's SEQ dim carries the model-axis shard; attention becomes a
# flash-decoding partial-softmax combine, inserted by SPMD).
DECODE_OVERRIDES = {
    "cache_seq": "model",
    "kv_heads": None,        # cache_seq holds the model axis (no duplicates)
}

# long_500k: batch=1 frees the data axis — shard cache seq over BOTH axes.
LONG_CONTEXT_OVERRIDES = {
    "batch": None,
    "cache_seq": ("data", "model"),
    "kv_heads": None,
    "experts": None,         # "data" carries cache_seq here
}


def _rules():
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def make_rules(mesh: Mesh, overrides: Optional[dict] = None) -> dict:
    """DEFAULT_RULES + overrides, restricted to axes the mesh actually has."""
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)

    def filt(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        return axes if len(axes) > 1 else (axes[0] if axes else None)

    return {k: filt(v) for k, v in rules.items()}


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, overrides: Optional[dict] = None):
    rules = make_rules(mesh, overrides)
    prev = (_mesh(), _rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def logical_spec(axes: Sequence, mesh: Mesh, rules: dict,
                 shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-divisible dims."""
    parts = []
    for i, name in enumerate(axes):
        if name is None:
            parts.append(None)
            continue
        ax = rules.get(name)
        if ax is None:
            parts.append(None)
            continue
        if shape is not None and shape[i] % _axis_size(mesh, ax) != 0:
            parts.append(None)
            continue
        parts.append(ax)
    return P(*parts)


def shard(x, axes: Sequence):
    """Annotate activation x with logical axes (identity off-mesh)."""
    mesh, rules = _mesh(), _rules()
    if mesh is None:
        return x
    spec = logical_spec(axes, mesh, rules, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter shardings (by name pattern)
# ---------------------------------------------------------------------------

PARAM_LOGICAL = {
    # attention
    "wq": ("embed_fsdp", "heads_flat"),
    "wk": ("embed_fsdp", "heads_flat"),
    "wv": ("embed_fsdp", "heads_flat"),
    "wo": ("heads_flat", "embed_fsdp"),
    "q_norm": (None,), "k_norm": (None,),
    # mlp
    "w1": ("embed_fsdp", "ff"), "w3": ("embed_fsdp", "ff"),
    "w2": ("ff", "embed_fsdp"),
    # moe: experts over "data" (EP), ff over "model" (TP within expert)
    "router": ("embed_fsdp", None),
    "we1": ("experts", None, "ff"), "we3": ("experts", None, "ff"),
    "we2": ("experts", "ff", None),
    # embeddings / head
    "embedding": ("vocab", "embed_fsdp"),
    "lm_head": ("embed_fsdp", "vocab"),
    # rwkv
    "wr": ("embed_fsdp", "d_inner"), "wk_r": ("embed_fsdp", "d_inner"),
    "wv_r": ("embed_fsdp", "d_inner"), "wg": ("embed_fsdp", "d_inner"),
    "wo_r": ("d_inner", "embed_fsdp"),
    "ck": ("embed_fsdp", "ff"), "cv": ("ff", "embed_fsdp"), "cr": ("embed_fsdp", None),
    # mamba
    "in_proj": ("embed_fsdp", "d_inner"),
    "out_proj": ("d_inner", "embed_fsdp"),
    "x_proj": ("d_inner", None), "dt_proj": (None, "d_inner"),
    "conv_w": (None, "d_inner"), "conv_b": ("d_inner",),
    "a_log": ("d_inner", None), "dcoef": ("d_inner",),
}


# Pure-EP layout (experts carry the SAME axis as "ff" would): each device
# owns whole experts, so neither expert matmul contracts a sharded dim — no
# per-layer (tokens, d_model) all-reduce.  Expert weights FSDP over the
# d_model dim instead.  Selected whenever rules map "experts" to the same
# axis as "ff" (see moe.moe_ffn which drops its ff constraint then).
PARAM_LOGICAL_EP = {
    "we1": ("experts", "embed_fsdp", None),
    "we3": ("experts", "embed_fsdp", None),
    "we2": ("experts", None, "embed_fsdp"),
}


def pure_ep(rules: dict) -> bool:
    e, f = rules.get("experts"), rules.get("ff")
    return e is not None and e == f


def param_spec_for(path: tuple, leaf_shape: tuple, mesh: Mesh, rules: dict) -> P:
    """Spec for a param leaf from the last name component in its path."""
    name = path[-1]
    # layer-stacked params have a leading blocks dim
    logical = (PARAM_LOGICAL_EP.get(name) if pure_ep(rules) else None) \
        or PARAM_LOGICAL.get(name)
    if logical is None:
        return P()
    extra = len(leaf_shape) - len(logical)
    axes = (None,) * extra + tuple(logical)
    return logical_spec(axes, mesh, rules, leaf_shape)


def tree_param_specs(params, mesh: Mesh, rules: Optional[dict] = None):
    rules = rules if rules is not None else make_rules(mesh)

    def walk(path, leaf):
        return NamedSharding(mesh, param_spec_for(
            tuple(p.key for p in path), leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(walk, params)
