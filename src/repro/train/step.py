"""Training step: loss (CE + MoE aux + z-loss), grad, AdamW update, remat."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.optim import adamw
from repro.train import sharding as shd


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-4
    remat: str = "full"            # full | dots | none
    ce_chunks: int = 16            # chunked big-vocab CE (never materialize
                                   # the full (tokens, vocab) logits)
    unroll: object = False         # block-scan unroll: False/True/int
    ce_unroll: bool = False        # unroll the CE chunk scan (accounting)
    microbatches: int = 1          # gradient accumulation (activation peak /k)


def cross_entropy(logits, labels, z_loss_weight: float = 0.0):
    """Mean CE over all positions.  logits (B,S,V) f32-upcast; labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    if z_loss_weight:
        ce = ce + z_loss_weight * jnp.square(lse).mean()
    return ce


def chunked_cross_entropy(x, lm_head, labels, z_loss_weight: float = 0.0,
                          num_chunks: int = 16, unroll: bool = False):
    """CE without materializing (tokens, vocab): project + reduce per chunk.

    x: (B,S,D) final hidden; lm_head: (D,V); labels: (B,S).  The chunk loop
    is a lax.scan (rematerialized on backward) — peak logits memory is
    (tokens/num_chunks, V) instead of (tokens, V), the standard big-vocab
    trick (e.g. 152k-vocab qwen3 at 1M tokens: 318 TB -> 20 GB global).
    """
    B, S, D = x.shape
    T = B * S
    while S % num_chunks:
        num_chunks //= 2
    # chunk along SEQ so the batch dim keeps its data sharding
    xf = x.reshape(B, num_chunks, S // num_chunks, D).transpose(1, 0, 2, 3)
    lf = labels.reshape(B, num_chunks, S // num_chunks).transpose(1, 0, 2)

    V = lm_head.shape[-1]

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk(carry, xs):
        ce_sum, z_sum = carry
        xc, lc = xs                                   # (B, S/nc, D), (B, S/nc)
        logits = jnp.einsum("bsd,dv->bsv", xc, lm_head).astype(jnp.float32)
        logits = shd.shard(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        # masked reduction instead of gather: partitions cleanly when the
        # vocab dim is sharded (a gather over a sharded dim forces GSPMD to
        # materialize the full logits per device)
        vids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vids == lc[..., None], logits, 0.0), axis=-1)
        return (ce_sum + (lse - gold).sum(), z_sum + jnp.square(lse).sum()), None

    (ce_sum, z_sum), _ = jax.lax.scan(
        chunk, (jnp.float32(0.0), jnp.float32(0.0)), (xf, lf),
        unroll=num_chunks if unroll else 1)
    ce = ce_sum / T
    if z_loss_weight:
        ce = ce + z_loss_weight * z_sum / T
    return ce


def _remat_policy(kind: str):
    if kind == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return None  # full recompute


def make_loss_fn(cfg, tcfg: TrainConfig):
    def loss_fn(params, batch):
        # remat is applied PER BLOCK inside forward_hidden (scan body
        # checkpointing) — wrapping the whole forward would save nothing.
        inputs = batch["inputs"]
        x, aux, _ = transformer.forward_hidden(
            params, inputs, cfg, unroll=tcfg.unroll, remat=tcfg.remat)
        loss = chunked_cross_entropy(
            x, params["lm_head"], batch["labels"], tcfg.z_loss_weight,
            tcfg.ce_chunks, tcfg.ce_unroll)
        total = loss + tcfg.aux_loss_weight * aux
        return total, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}.  Pure function of its inputs —
    jit/lower it with the shardings from train/sharding.py.
    """
    loss_fn = make_loss_fn(cfg, tcfg)

    def _grads(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over k microbatches; activation peak
        # is one microbatch's, grads accumulate in param dtype
        k = tcfg.microbatches
        mb = jax.tree_util.tree_map(
            lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]), batch)

        def acc(carry, mbatch):
            (tot, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch)
            gsum, tsum, csum, asum = carry
            gsum = jax.tree_util.tree_map(
                lambda s, gi: s + gi.astype(s.dtype), gsum, g)
            return (gsum, tsum + tot, csum + met["ce"], asum + met["aux"]), None

        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        (gsum, tot, ce, aux), _ = jax.lax.scan(
            acc, (zeros, 0.0, 0.0, 0.0), mb)
        grads = jax.tree_util.tree_map(lambda g: g / k, gsum)
        return (tot / k, {"ce": ce / k, "aux": aux / k}), grads

    def train_step(state, batch):
        (total, metrics), grads = _grads(state["params"], batch)
        params, opt = adamw.apply_updates(
            state["params"], grads, state["opt"], tcfg.optimizer)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return {"params": params, "opt": opt}, {
            "loss": total, "ce": metrics["ce"], "aux": metrics["aux"],
            "grad_norm": gnorm, "lr": adamw.schedule(tcfg.optimizer, opt["step"])}

    return train_step


def init_train_state(key, cfg, tcfg: TrainConfig):
    params = transformer.init_params(key, cfg)
    return {"params": params, "opt": adamw.init_state(params, tcfg.optimizer)}
