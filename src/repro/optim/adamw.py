"""AdamW + cosine schedule, pure-pytree (no optax dependency).

`moments_dtype` lets very large models (jamba-398b) keep m/v in bf16 so the
optimizer state fits the single-pod HBM budget (see DESIGN.md / EXPERIMENTS
§Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"   # or "bfloat16" for very large models


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.moments_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"])
    newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree_util.tree_map(lambda t: t[1], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree_util.tree_map(lambda t: t[2], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return newp, {"mu": newm, "nu": newv, "step": step}
