"""Pallas TPU kernel for the Alg. 1 supersplit scan (the DRF hot loop).

GPU/CPU papers stream rows one at a time (Alg. 1's `for (a,y,i) in q(j)`);
a TPU wants the same *semantics* re-blocked for the MXU/VPU and the
HBM→VMEM hierarchy.  The adaptation (DESIGN.md §2):

  * grid = (feature, row_block): row blocks stream sequentially per feature
    (one HBM→VMEM pass per column per level — the paper's "read sequentially,
    no random access"),
  * the per-leaf histogram state H ∈ (L+1, S), last-seen value v, and
    running best (gain, threshold) live in VMEM scratch and persist across
    row blocks (the scan carry),
  * within a block the sequential dependence is broken with an EXCLUSIVE
    per-leaf prefix computed as one strict-lower-triangular matmul
    (Bn × Bn) @ (Bn, (L+1)·S) — MXU work instead of a serial loop,
  * the "previous in-bag value per leaf" needs a running max, computed with
    log2(Bn) shift-max steps (VPU).

Exactness: identical split choices to `repro.core.splits.best_numeric_split_scan`
up to float summation order (verified in tests against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = float("-inf")  # plain float: Pallas kernels must not capture array consts


def _impurity(h: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Weighted (N·) impurity for stats (..., S)."""
    if kind == "gini":
        n = h.sum(-1)
        return n - jnp.where(n > 0, (h * h).sum(-1) / jnp.maximum(n, 1e-12), 0.0)
    if kind == "entropy":
        n = h.sum(-1, keepdims=True)
        p = h / jnp.maximum(n, 1e-12)
        plogp = jnp.where(h > 0, p * jnp.log(jnp.maximum(p, 1e-12)), 0.0)
        return -(n[..., 0] * plogp.sum(-1))
    if kind == "variance":
        w, wy, wy2 = h[..., 0], h[..., 1], h[..., 2]
        return jnp.maximum(wy2 - jnp.where(w > 0, wy * wy / jnp.maximum(w, 1e-12), 0.0), 0.0)
    raise ValueError(kind)


def _count(h: jnp.ndarray, task: str) -> jnp.ndarray:
    return h.sum(-1) if task == "classification" else h[..., 0]


def _row_stats(y: jnp.ndarray, w: jnp.ndarray, s_dim: int, task: str) -> jnp.ndarray:
    if task == "classification":
        cls = jax.nn.one_hot(y.astype(jnp.int32), s_dim, dtype=jnp.float32)
        return cls * w[:, None]
    yf = y.astype(jnp.float32)
    return jnp.stack([w, w * yf, w * yf * yf], axis=-1)


def _excl_cummax(m: jnp.ndarray) -> jnp.ndarray:
    """Exclusive running max along axis 0 via log-steps (B, L) -> (B, L)."""
    b = m.shape[0]
    out = jnp.concatenate([jnp.full((1,) + m.shape[1:], NEG), m[:-1]], axis=0)
    shift = 1
    while shift < b:
        shifted = jnp.concatenate(
            [jnp.full((shift,) + m.shape[1:], NEG), out[:-shift]], axis=0)
        out = jnp.maximum(out, shifted)
        shift *= 2
    return out


def _split_scan_kernel(vals_ref, leaf_ref, w_ref, y_ref, cand_ref, totals_ref,
                       gain_ref, thr_ref,
                       h_scr, v_scr, bs_scr, bt_scr,
                       *, L1: int, s_dim: int, bn: int, nblocks: int,
                       impurity: str, task: str, min_records: float):
    """One (feature, row_block) grid step."""
    jb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        h_scr[...] = jnp.zeros((L1, s_dim), jnp.float32)
        v_scr[...] = jnp.full((1, L1), jnp.inf, jnp.float32)   # "null" sentinel
        bs_scr[...] = jnp.full((1, L1), NEG)
        bt_scr[...] = jnp.zeros((1, L1), jnp.float32)

    vals = vals_ref[0, :]                      # (Bn,)
    leaf = leaf_ref[0, :].astype(jnp.int32)
    w = w_ref[0, :]
    y = y_ref[0, :]
    cand = cand_ref[0, :]                      # (L1,) float mask
    totals = totals_ref[0]                     # (L1, S)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (bn, L1), 1)
    onehot = (lanes == leaf[:, None]).astype(jnp.float32)
    inbag = (w > 0) & (leaf > 0)
    # gather cand[leaf] as a one-hot contraction (TPU-friendly, no gather)
    cand_k = jnp.sum(onehot * cand[None, :], axis=1)
    active = inbag & (cand_k > 0)
    oh_act = onehot * active[:, None].astype(jnp.float32)

    stats = _row_stats(y, w, s_dim, task) * active[:, None]   # (Bn, S)
    contrib = oh_act[:, :, None] * stats[:, None, :]          # (Bn, L1, S)
    flat = contrib.reshape(bn, L1 * s_dim)

    # exclusive per-leaf prefix within the block: strict lower-triangular matmul
    tril = (jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)).astype(jnp.float32)
    local_excl = jax.lax.dot(tril, flat,
                             precision=jax.lax.Precision.HIGHEST)
    left_full = h_scr[...][None] + local_excl.reshape(bn, L1, s_dim)
    left = jnp.sum(left_full * onehot[:, :, None], axis=1)    # (Bn, S) gather
    tot_k = jnp.sum(totals[None] * onehot[:, :, None], axis=1)
    right = tot_k - left

    # previous in-bag value per leaf (values ascend within a column)
    mvals = jnp.where((onehot > 0) & inbag[:, None], vals[:, None], NEG)
    pv_local = _excl_cummax(mvals)                            # (Bn, L1)
    v_carry = v_scr[0]                                        # (L1,) +inf = none
    v_carry_neg = jnp.where(jnp.isfinite(v_carry), v_carry, NEG)
    pv_all = jnp.maximum(pv_local, v_carry_neg[None, :])
    pv = jnp.max(jnp.where(onehot > 0, pv_all, NEG), axis=1)  # (Bn,)

    tau = (vals + pv) * 0.5
    parent_imp = _impurity(left + right, impurity)
    gain = parent_imp - _impurity(left, impurity) - _impurity(right, impurity)
    ok = active & (vals > pv) & (pv > NEG) \
        & (_count(left, task) >= min_records) \
        & (_count(right, task) >= min_records)
    gain = jnp.where(ok, gain, NEG)

    # per-leaf best within the block, first-row tie-break (scan order)
    gmat = jnp.where(onehot > 0, gain[:, None], NEG)          # (Bn, L1)
    blk_best = jnp.max(gmat, axis=0)                          # (L1,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, L1), 0)
    first = jnp.min(jnp.where(gmat >= blk_best[None, :], rows, bn), axis=0)
    first_c = jnp.clip(first, 0, bn - 1)
    blk_thr = jnp.sum(
        jnp.where((rows == first_c[None, :]), tau[:, None], 0.0), axis=0)

    better = blk_best > bs_scr[0]
    bs_scr[...] = jnp.where(better, blk_best, bs_scr[0])[None]
    bt_scr[...] = jnp.where(better, blk_thr, bt_scr[0])[None]

    # carry updates
    h_scr[...] = h_scr[...] + contrib.sum(axis=0)
    blk_last = jnp.max(mvals, axis=0)                         # (L1,)
    new_v = jnp.maximum(v_carry_neg, blk_last)
    v_scr[...] = jnp.where(jnp.isfinite(new_v), new_v, jnp.inf)[None]

    @pl.when(jb == nblocks - 1)
    def _emit():
        gain_ref[...] = bs_scr[...]
        thr_ref[...] = bt_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("L1", "s_dim", "bn", "impurity", "task", "min_records",
                     "interpret"))
def split_scan_pallas(
    vals: jnp.ndarray,     # (m, n) sorted values per feature
    leaf: jnp.ndarray,     # (m, n) int32 leaf ids in sorted order
    w: jnp.ndarray,        # (m, n) bag weights in sorted order
    y: jnp.ndarray,        # (m, n) labels in sorted order
    cand: jnp.ndarray,     # (m, L1) float32 candidate mask (leaf 0 = 0)
    totals: jnp.ndarray,   # (m, L1, S) global per-leaf stat totals
    *, L1: int, s_dim: int, bn: int = 256,
    impurity: str = "gini", task: str = "classification",
    min_records: float = 1.0, interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Best (gain, threshold) per (feature, leaf): (m, L1) each."""
    m, n = vals.shape
    assert n % bn == 0, f"n={n} must be a multiple of bn={bn} (pad rows)"
    nblocks = n // bn
    grid = (m, nblocks)

    kernel = functools.partial(
        _split_scan_kernel, L1=L1, s_dim=s_dim, bn=bn, nblocks=nblocks,
        impurity=impurity, task=task, min_records=min_records)

    row_spec = pl.BlockSpec((1, bn), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((1, L1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, row_spec,
                  pl.BlockSpec((1, L1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, L1, s_dim), lambda i, j: (i, 0, 0))],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((m, L1), jnp.float32),
                   jax.ShapeDtypeStruct((m, L1), jnp.float32)],
        scratch_shapes=[
            # VMEM carries: histogram, last value, best gain, best threshold
            pltpu.VMEM((L1, s_dim), jnp.float32),
            pltpu.VMEM((1, L1), jnp.float32),
            pltpu.VMEM((1, L1), jnp.float32),
            pltpu.VMEM((1, L1), jnp.float32),
        ],
        interpret=interpret,
    )(vals, leaf, w, y, cand, totals)
