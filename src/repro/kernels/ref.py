"""Pure-jnp oracles for the Pallas kernels.

`split_scan_ref` is the faithful Alg. 1 sequential scan
(`repro.core.splits.best_numeric_split_scan`) vmapped over columns — the
semantics the TPU kernel must reproduce.  `cat_hist_ref` is a plain
segment-sum count table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import splits


@functools.partial(jax.jit, static_argnames=("L1", "s_dim", "impurity", "task",
                                             "min_records"))
def split_scan_ref(vals, leaf, w, y, cand, totals, *, L1, s_dim,
                   impurity="gini", task="classification", min_records=1.0):
    """Same contract as kernels.split_scan.split_scan_pallas.

    vals/leaf/w/y: (m, n) in per-column presorted order; cand: (m, L1)
    float mask; totals: (m, L1, S).  Returns (gain (m, L1), thr (m, L1)).
    """
    def per_col(v, lf, ww, yy, cl, tot):
        stats = splits.row_stats(yy, ww, s_dim, task)
        return splits.best_numeric_split_scan(
            v, lf, ww, stats, cl > 0, L1 - 1, impurity, task, min_records,
            totals=tot)

    return jax.vmap(per_col)(vals, leaf, w, y, cand, totals)


@functools.partial(jax.jit, static_argnames=("L1", "V", "s_dim", "task"))
def cat_hist_ref(x, leaf, w, y, *, L1, V, s_dim, task="classification"):
    """Count table (m, L1, V, S) — one pass per column."""
    def col(xc, lf, ww, yy):
        stats = splits.row_stats(yy, ww, s_dim, task)
        inbag = (ww > 0) & (lf > 0)
        contrib = jnp.where(inbag[:, None], stats, 0.0)
        flat = lf * V + xc
        return jax.ops.segment_sum(contrib, flat, num_segments=L1 * V).reshape(L1, V, s_dim)

    return jax.vmap(col)(x, leaf, w, y)
