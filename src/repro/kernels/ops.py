"""jit'd wrappers around the Pallas kernels.

These adapt the tree builder's (sorted_idx, leaf_of, w, labels) state to the
kernels' pre-gathered blocked layout, handle padding (row blocks, leaf-lane
alignment, arity blocks), and select interpret mode automatically off-TPU.
The `"kernel"` numeric backend used by `tree.TreeParams(backend="kernel")`
lands here, as does the kernel categorical path of the fused level step.

Both entry points take the stat dimension from the caller (`num_classes`):
deriving it from `labels.max()` would be a per-call device->host sync in the
middle of the level loop (and is impossible under jit).  The seed behaviour
is kept as an eager-only fallback when `num_classes` is omitted.

Both entry points also batch over a leading TREE axis: `tree.build_forest`
vmaps them over per-tree (leaf_of, w) state, and `pallas_call`'s batching
rule folds that axis into the kernel grid — one kernel launch for the
whole tree batch, bit-identical per tree to the unbatched call
(tests/test_forest_batch.py exercises this through the `kernel` backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import splits
from repro.kernels import cat_hist, feat_hist, split_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(n: int, bn: int) -> int:
    return (-n) % bn


# --- interpret-mode compile-cost bounds -----------------------------------
#
# Off-TPU the Pallas kernels run in interpret mode, where the sequential
# row-block grid is UNROLLED at trace time: the lowered program contains one
# copy of the kernel body per block, so with the default bn=256 a fused
# level step at n≫1M would emit thousands of body copies and compile
# pathologically (ROADMAP "kernel-backend compile cost at scale").  The
# plan below bounds the unrolled block count by growing the block size —
# the body stays ONE set of ops, only operand shapes grow — and, for the
# split_scan kernel only (whose in-block prefix is a Bn×Bn triangular
# matmul, O(bn²) memory/work), gates to the exact jnp `segment` engine once
# the grown block would exceed _MAX_INTERPRET_BN.  On TPU nothing changes:
# the grid is a real sequential grid, not an unroll.

_MAX_INTERPRET_ROW_BLOCKS = 64
_MAX_INTERPRET_BN = 2048


def _interpret_grid_plan(n: int, bn: int,
                         quadratic: bool = False) -> tuple[int, int, bool]:
    """(bn_eff, nblocks, gated) bounding the interpret-mode grid.

    nblocks <= _MAX_INTERPRET_ROW_BLOCKS always; `gated=True` (only
    possible with quadratic=True) means the caller must fall back to a
    non-Pallas exact engine instead.
    """
    blocks = max(1, -(-n // bn))
    if blocks <= _MAX_INTERPRET_ROW_BLOCKS:
        return bn, blocks, False
    bn_eff = -(-n // _MAX_INTERPRET_ROW_BLOCKS)
    bn_eff += (-bn_eff) % 128                  # keep lane alignment
    if quadratic and bn_eff > _MAX_INTERPRET_BN:
        return bn, blocks, True
    return bn_eff, max(1, -(-n // bn_eff)), False


def _stat_dim(labels, num_classes, task: str) -> int:
    if task != "classification":
        return 3
    if num_classes is None:
        # eager-only fallback (device sync); pass num_classes to avoid it
        return max(int(labels.max()) + 1, 2)
    return max(int(num_classes), 2)


def split_scan_supersplit(sorted_vals, sorted_idx, leaf_of, w, labels,
                          cand, Lp, impurity="gini", task="classification",
                          min_records=1.0, bn=256, interpret=None,
                          num_classes=None):
    """All-columns supersplit via the Pallas kernel.

    sorted_vals/sorted_idx: (m, n); cand: (m, Lp+1) bool;
    returns (gain (m, Lp+1), thr (m, Lp+1)) matching the jnp backends.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, n = sorted_vals.shape
    L1 = Lp + 1
    s_dim = _stat_dim(labels, num_classes, task)

    if interpret:
        bn, _, gated = _interpret_grid_plan(n, bn, quadratic=True)
        if gated:
            # n too large for a bounded-unroll Pallas interpret program:
            # answer with the exact vectorized jnp engine instead (same
            # split choices up to float summation order — the same
            # tolerance the kernel itself is held to vs the scan spec)
            stats = splits.row_stats(labels, w, s_dim, task)

            def per_col(v, s, c):
                return splits.best_numeric_split_segment(
                    v, leaf_of[s], w[s], stats[s], c, Lp, impurity, task,
                    min_records)
            return jax.vmap(per_col)(sorted_vals, sorted_idx, cand)

    leaf_g = leaf_of[sorted_idx]                      # (m, n)
    w_g = w[sorted_idx]
    y_g = labels[sorted_idx].astype(jnp.float32)

    pad = _pad_rows(n, bn)
    if pad:
        sorted_vals = jnp.pad(sorted_vals, ((0, 0), (0, pad)))
        leaf_g = jnp.pad(leaf_g, ((0, 0), (0, pad)))       # leaf 0 = closed
        w_g = jnp.pad(w_g, ((0, 0), (0, pad)))             # w 0 = skipped
        y_g = jnp.pad(y_g, ((0, 0), (0, pad)))

    # global per-leaf totals per column (cheap; exact "right" histograms)
    def tot(lf, ww, yy):
        if task == "classification":
            st = jax.nn.one_hot(yy.astype(jnp.int32), s_dim) * ww[:, None]
        else:
            st = jnp.stack([ww, ww * yy, ww * yy * yy], -1)
        st = jnp.where(((ww > 0) & (lf > 0))[:, None], st, 0.0)
        return jax.ops.segment_sum(st, lf, num_segments=L1)

    totals = jax.vmap(tot)(leaf_g, w_g, y_g)          # (m, L1, S)

    return split_scan.split_scan_pallas(
        sorted_vals, leaf_g, w_g, y_g, cand.astype(jnp.float32), totals,
        L1=L1, s_dim=s_dim, bn=bn, impurity=impurity, task=task,
        min_records=min_records, interpret=interpret)


def categorical_tables(cat_cols, leaf_of, w, labels, *, V, Lp,
                       task="classification", bn=256, bv=None, interpret=None,
                       num_classes=None):
    """Count tables (m_cat, Lp+1, V, S) via the Pallas cat_hist kernel.

    Arbitrary arity V is supported: the category axis is padded up to a
    multiple of the kernel's category-block `bv` (values >= V never occur in
    the data, so the padded lanes stay zero) and the result is sliced back.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, n = cat_cols.shape
    s_dim = _stat_dim(labels, num_classes, task)
    if interpret:
        # bound the unrolled row-block count (body work is linear in bn
        # here — the one-hot matmul — so growing the block never gates)
        bn, _, _ = _interpret_grid_plan(n, bn)
    bv = bv or cat_hist.default_bv(V, Lp + 1)
    Vp = V + (-V) % bv
    pad = _pad_rows(n, bn)
    leaf_b = jnp.broadcast_to(leaf_of, (m, n))
    w_b = jnp.broadcast_to(w, (m, n))
    y_b = jnp.broadcast_to(labels.astype(jnp.float32), (m, n))
    if pad:
        cat_cols = jnp.pad(cat_cols, ((0, 0), (0, pad)))
        leaf_b = jnp.pad(leaf_b, ((0, 0), (0, pad)))
        w_b = jnp.pad(w_b, ((0, 0), (0, pad)))
        y_b = jnp.pad(y_b, ((0, 0), (0, pad)))
    tables = cat_hist.cat_hist_pallas(
        cat_cols, leaf_b, w_b, y_b, L1=Lp + 1, V=Vp, s_dim=s_dim, bv=bv,
        bn=bn, task=task, interpret=interpret)
    return tables[:, :, :V, :] if Vp != V else tables


def feature_tables(bin_of, leaf_ids, w, labels, *, B, W,
                   task="classification", bn=256, bv=None, interpret=None,
                   num_classes=None):
    """Histogram tables (m, W, B, S) for ALL features in ONE pass over the
    row blocks, via the Pallas `feat_hist` kernel.

    bin_of: (m, n) bit-packed bucket ids; leaf_ids: (n,) scatter slots
    (0 = discard; raw leaf ids on the plain path, packed build slots on
    the subtraction path — see level/engines.py); W = slot-axis width.
    The jnp twin is `splits.feature_count_tables` (one flat segment_sum)
    — same accumulation order, so backends agree (bit-identically for the
    integer classification stats).  Arbitrary B is supported by padding
    the bucket axis to the kernel's bucket block `bv` and slicing back.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, n = bin_of.shape
    s_dim = _stat_dim(labels, num_classes, task)
    if interpret:
        # bound the unrolled row-block count (body work per block is
        # linear in bn — the per-feature one-hot matmuls — so growing the
        # block never gates)
        bn, _, _ = _interpret_grid_plan(n, bn)
    bv = bv or feat_hist.default_bv(B, W, m)
    Bp = B + (-B) % bv
    pad = _pad_rows(n, bn)
    leaf = leaf_ids.astype(jnp.int32)
    wv = w
    y = labels.astype(jnp.float32)
    if pad:
        bin_of = jnp.pad(bin_of, ((0, 0), (0, pad)))   # bin 0, but leaf 0 =
        leaf = jnp.pad(leaf, (0, pad))                 # discarded anyway
        wv = jnp.pad(wv, (0, pad))                     # w 0 = skipped
        y = jnp.pad(y, (0, pad))
    tables = feat_hist.feat_hist_pallas(
        bin_of, leaf, wv, y, L1=W, V=Bp, s_dim=s_dim, bv=bv, bn=bn,
        task=task, interpret=interpret)
    return tables[:, :, :B, :] if Bp != B else tables
