"""jit'd wrappers around the Pallas kernels.

These adapt the tree builder's (sorted_idx, leaf_of, w, labels) state to the
kernels' pre-gathered blocked layout, handle padding (row blocks, leaf-lane
alignment, arity blocks), and select interpret mode automatically off-TPU.
The `"kernel"` numeric backend used by `tree.TreeParams(backend="kernel")`
lands here, as does the kernel categorical path of the fused level step.

Both entry points take the stat dimension from the caller (`num_classes`):
deriving it from `labels.max()` would be a per-call device->host sync in the
middle of the level loop (and is impossible under jit).  The seed behaviour
is kept as an eager-only fallback when `num_classes` is omitted.

Both entry points also batch over a leading TREE axis: `tree.build_forest`
vmaps them over per-tree (leaf_of, w) state, and `pallas_call`'s batching
rule folds that axis into the kernel grid — one kernel launch for the
whole tree batch, bit-identical per tree to the unbatched call
(tests/test_forest_batch.py exercises this through the `kernel` backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cat_hist, split_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(n: int, bn: int) -> int:
    return (-n) % bn


def _stat_dim(labels, num_classes, task: str) -> int:
    if task != "classification":
        return 3
    if num_classes is None:
        # eager-only fallback (device sync); pass num_classes to avoid it
        return max(int(labels.max()) + 1, 2)
    return max(int(num_classes), 2)


def split_scan_supersplit(sorted_vals, sorted_idx, leaf_of, w, labels,
                          cand, Lp, impurity="gini", task="classification",
                          min_records=1.0, bn=256, interpret=None,
                          num_classes=None):
    """All-columns supersplit via the Pallas kernel.

    sorted_vals/sorted_idx: (m, n); cand: (m, Lp+1) bool;
    returns (gain (m, Lp+1), thr (m, Lp+1)) matching the jnp backends.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, n = sorted_vals.shape
    L1 = Lp + 1
    s_dim = _stat_dim(labels, num_classes, task)

    leaf_g = leaf_of[sorted_idx]                      # (m, n)
    w_g = w[sorted_idx]
    y_g = labels[sorted_idx].astype(jnp.float32)

    pad = _pad_rows(n, bn)
    if pad:
        sorted_vals = jnp.pad(sorted_vals, ((0, 0), (0, pad)))
        leaf_g = jnp.pad(leaf_g, ((0, 0), (0, pad)))       # leaf 0 = closed
        w_g = jnp.pad(w_g, ((0, 0), (0, pad)))             # w 0 = skipped
        y_g = jnp.pad(y_g, ((0, 0), (0, pad)))

    # global per-leaf totals per column (cheap; exact "right" histograms)
    def tot(lf, ww, yy):
        if task == "classification":
            st = jax.nn.one_hot(yy.astype(jnp.int32), s_dim) * ww[:, None]
        else:
            st = jnp.stack([ww, ww * yy, ww * yy * yy], -1)
        st = jnp.where(((ww > 0) & (lf > 0))[:, None], st, 0.0)
        return jax.ops.segment_sum(st, lf, num_segments=L1)

    totals = jax.vmap(tot)(leaf_g, w_g, y_g)          # (m, L1, S)

    return split_scan.split_scan_pallas(
        sorted_vals, leaf_g, w_g, y_g, cand.astype(jnp.float32), totals,
        L1=L1, s_dim=s_dim, bn=bn, impurity=impurity, task=task,
        min_records=min_records, interpret=interpret)


def categorical_tables(cat_cols, leaf_of, w, labels, *, V, Lp,
                       task="classification", bn=256, bv=None, interpret=None,
                       num_classes=None):
    """Count tables (m_cat, Lp+1, V, S) via the Pallas cat_hist kernel.

    Arbitrary arity V is supported: the category axis is padded up to a
    multiple of the kernel's category-block `bv` (values >= V never occur in
    the data, so the padded lanes stay zero) and the result is sliced back.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, n = cat_cols.shape
    s_dim = _stat_dim(labels, num_classes, task)
    bv = bv or cat_hist.default_bv(V, Lp + 1)
    Vp = V + (-V) % bv
    pad = _pad_rows(n, bn)
    leaf_b = jnp.broadcast_to(leaf_of, (m, n))
    w_b = jnp.broadcast_to(w, (m, n))
    y_b = jnp.broadcast_to(labels.astype(jnp.float32), (m, n))
    if pad:
        cat_cols = jnp.pad(cat_cols, ((0, 0), (0, pad)))
        leaf_b = jnp.pad(leaf_b, ((0, 0), (0, pad)))
        w_b = jnp.pad(w_b, ((0, 0), (0, pad)))
        y_b = jnp.pad(y_b, ((0, 0), (0, pad)))
    tables = cat_hist.cat_hist_pallas(
        cat_cols, leaf_b, w_b, y_b, L1=Lp + 1, V=Vp, s_dim=s_dim, bv=bv,
        bn=bn, task=task, interpret=interpret)
    return tables[:, :, :V, :] if Vp != V else tables
