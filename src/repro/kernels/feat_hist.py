"""Pallas TPU kernel for multi-feature histogram tables (DESIGN.md §6).

`split_mode="hist"` builds, per depth level, a per-leaf (bin × stat) count
table for EVERY drawn numeric candidate column.  The `cat_hist` kernel
(which this generalizes) puts the feature index on the grid, so every
feature re-reads the shared per-row state (leaf ids, bag weights, labels)
— m× redundant HBM traffic for state that is identical across features.
This kernel instead makes ONE pass over the row blocks: the per-row state
and its stat contributions are loaded/computed once per block, and an
inner loop over features accumulates each feature's one-hot transpose
matmul (L1·Bv, Bn) @ (Bn, S) into a per-feature VMEM scratch slice.

The bin cache arrives BIT-PACKED (uint8 for <= 256 buckets, uint16 past —
presort.bin_dtype), so the per-feature traffic is 1 byte per row instead
of the 4 of the float32 column the exact engines read.  Like `cat_hist`,
deep tables are tiled over a bucket-block grid dimension Bv so the VMEM
scratch never exceeds m·L1·Bv·S floats; the histogram-subtraction path
(level/engines.py) halves L1 by packing build leaves, which doubles the
admissible Bv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cat_hist import _row_stats


def _feat_hist_kernel(x_ref, leaf_ref, w_ref, y_ref, out_ref, acc_scr, *,
                      m, L1, bv, bn, nblocks, s_dim, task):
    vb = pl.program_id(0)
    jb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        acc_scr[...] = jnp.zeros((m * L1 * bv, s_dim), jnp.float32)

    # shared per-row state: read and reduced ONCE per row block, reused by
    # every feature (the cat_hist kernel re-reads these per feature)
    leaf = leaf_ref[0, :].astype(jnp.int32)                   # (Bn,)
    w = w_ref[0, :]
    y = y_ref[0, :]
    stats = _row_stats(y, w, s_dim, task)                     # (Bn, S)
    inbag0 = (w > 0) & (leaf > 0)
    v0 = vb * bv
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bn, L1 * bv), 1)

    def per_feature(f, carry):
        x = pl.load(x_ref, (pl.ds(f, 1), slice(None)))[0].astype(jnp.int32)
        in_range = (x >= v0) & (x < v0 + bv)
        inbag = inbag0 & in_range
        comb = leaf * bv + jnp.clip(x - v0, 0, bv - 1)        # (Bn,)
        onehot = ((lanes == comb[:, None])
                  & inbag[:, None]).astype(jnp.float32)
        st = stats * inbag[:, None].astype(jnp.float32)
        upd = jax.lax.dot(onehot.T, st,
                          precision=jax.lax.Precision.HIGHEST)
        rows = pl.ds(f * (L1 * bv), L1 * bv)
        cur = pl.load(acc_scr, (rows, slice(None)))
        pl.store(acc_scr, (rows, slice(None)), cur + upd)
        return carry

    jax.lax.fori_loop(0, m, per_feature, 0)

    @pl.when(jb == nblocks - 1)
    def _emit():
        out_ref[...] = acc_scr[...].reshape(m, L1, bv, s_dim)


def default_bv(V: int, L1: int, m: int) -> int:
    """Bucket-block size keeping the VMEM scratch under ~m·L1·bv = 32k
    floats per stat lane (the whole-feature-set analogue of cat_hist's
    per-feature bound)."""
    return min(V, max(1, (1 << 15) // max(1, L1 * max(m, 1))))


@functools.partial(jax.jit, static_argnames=("L1", "V", "s_dim", "bv", "bn",
                                             "task", "interpret"))
def feat_hist_pallas(x, leaf, w, y, *, L1, V, s_dim, bv=None, bn=256,
                     task="classification", interpret=True):
    """Histogram tables (m, L1, V, S) for ALL m features in one row pass.

    x: (m, n) packed bucket ids (uint8/uint16); leaf/w/y: (n,) — shared
    across features, NOT pre-broadcast.  V must be a multiple of bv and n
    of bn; `kernels.ops.feature_tables` pads both for arbitrary shapes.
    `leaf` entries are scatter SLOTS (0 = discard): the subtraction path
    passes packed build-leaf slots, the plain path raw leaf ids.
    """
    m, n = x.shape
    bv = bv or default_bv(V, L1, m)
    assert n % bn == 0 and V % bv == 0
    grid = (V // bv, n // bn)
    kernel = functools.partial(_feat_hist_kernel, m=m, L1=L1, bv=bv, bn=bn,
                               nblocks=n // bn, s_dim=s_dim, task=task)
    row_spec = pl.BlockSpec((1, bn), lambda v, j: (0, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, bn), lambda v, j: (0, j)),
                  row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((m, L1, bv, s_dim), lambda v, j: (0, 0, v, 0)),
        out_shape=jax.ShapeDtypeStruct((m, L1, V, s_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m * L1 * bv, s_dim), jnp.float32)],
        interpret=interpret,
    )(x, leaf[None], w[None], y[None])
