"""Pallas TPU kernel for categorical count tables (paper §2.4 / §3.1).

"For categorical attributes, builds count tables 'attribute value × class →
number of records'" — per open leaf.  On TPU the scatter-add becomes a
one-hot transpose matmul per row block: (L1·Bv, Bn) @ (Bn, S) on the MXU,
accumulated in VMEM scratch across the sequential row-block grid dimension.
High-arity columns (the paper's Leo has arity up to 10'000) are tiled over
a category-block grid dimension Bv so the VMEM table never exceeds
L1·Bv·S floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_stats(y, w, s_dim, task):
    if task == "classification":
        return jax.nn.one_hot(y.astype(jnp.int32), s_dim, dtype=jnp.float32) * w[:, None]
    yf = y.astype(jnp.float32)
    return jnp.stack([w, w * yf, w * yf * yf], axis=-1)


def _cat_hist_kernel(x_ref, leaf_ref, w_ref, y_ref, out_ref, acc_scr,
                     *, L1, bv, bn, nblocks, s_dim, task):
    jb = pl.program_id(2)
    vb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        acc_scr[...] = jnp.zeros((L1 * bv, s_dim), jnp.float32)

    x = x_ref[0, :].astype(jnp.int32)          # (Bn,)
    leaf = leaf_ref[0, :].astype(jnp.int32)
    w = w_ref[0, :]
    y = y_ref[0, :]

    v0 = vb * bv
    in_range = (x >= v0) & (x < v0 + bv)
    inbag = (w > 0) & (leaf > 0) & in_range
    comb = leaf * bv + jnp.clip(x - v0, 0, bv - 1)           # (Bn,)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bn, L1 * bv), 1)
    onehot = ((lanes == comb[:, None]) & inbag[:, None]).astype(jnp.float32)
    stats = _row_stats(y, w, s_dim, task) * inbag[:, None].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] + jax.lax.dot(
        onehot.T, stats, precision=jax.lax.Precision.HIGHEST)

    @pl.when(jb == nblocks - 1)
    def _emit():
        out_ref[...] = acc_scr[...].reshape(1, L1, bv, s_dim)


def default_bv(V: int, L1: int) -> int:
    """Category-block size keeping the VMEM table under ~L1*4096 floats."""
    return min(V, max(1, 4096 // L1))


@functools.partial(jax.jit, static_argnames=("L1", "V", "s_dim", "bv", "bn",
                                             "task", "interpret"))
def cat_hist_pallas(x, leaf, w, y, *, L1, V, s_dim, bv=None, bn=256,
                    task="classification", interpret=True):
    """Count tables (m, L1, V, S) from per-column category values.

    x/leaf/w/y: (m, n) int32/int32/f32/f32 (row order irrelevant — counting
    is order-free, so no presorting needed for categorical columns, exactly
    as in the paper).  V must be a multiple of bv and n of bn; the
    `kernels.ops.categorical_tables` wrapper pads both for arbitrary shapes.
    """
    m, n = x.shape
    bv = bv or default_bv(V, L1)
    assert n % bn == 0 and V % bv == 0
    grid = (m, V // bv, n // bn)
    kernel = functools.partial(_cat_hist_kernel, L1=L1, bv=bv, bn=bn,
                               nblocks=n // bn, s_dim=s_dim, task=task)
    row_spec = pl.BlockSpec((1, bn), lambda i, v, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, L1, bv, s_dim), lambda i, v, j: (i, 0, v, 0)),
        out_shape=jax.ShapeDtypeStruct((m, L1, V, s_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((L1 * bv, s_dim), jnp.float32)],
        interpret=interpret,
    )(x, leaf, w, y)
