"""Fault-injection harness for streamed training (DESIGN.md §9).

Three failure modes, each mapping to a real large-scale incident:

* transient read failures  — a flaky disk / network filesystem read
  that succeeds on retry (`FaultyRowSource(transient=...)`);
* persistent read failures — a dead shard: every retry fails and the
  driver must escalate `StreamReadError` after flushing its checkpoint
  (`FaultyRowSource(persistent=...)`);
* process death            — SIGKILL at a scheduled read, after the
  Nth level snapshot, or in the worst atomic-write window (between the
  tmp write and `os.replace`): `kill_after_reads=`,
  `arm_kill_after_snapshots`, `arm_kill_mid_replace`.

SIGKILL (not an exception) is deliberate: nothing — no `finally`, no
atexit — runs, exactly like a preemption.  The kill-based hooks are
therefore only usable from a SUBPROCESS (tests/test_faults.py spawns
one, waits for returncode -9, then resumes in-process and asserts
node-for-node parity with the uninterrupted fit).
"""
from __future__ import annotations

import os
import signal

from repro.core import atomicio, checkpoint
from repro.core.dataset import RowSource


def sigkill_self() -> None:
    """Die like a preempted worker: no cleanup handlers run."""
    os.kill(os.getpid(), signal.SIGKILL)


class FaultyRowSource(RowSource):
    """A `RowSource` wrapper with scheduled read failures.

    Read indices count LOGICAL reads (completed `bins_block` /
    `bins_take` calls): retries of a failing read observe the same
    index, so `transient={i: k}` makes logical read i fail k times and
    then succeed — precisely the contract `read_with_retry` is built
    for — while `persistent={i}` makes it fail on every attempt.

    The wrapper inherits the inner source's identity (labels, edges,
    task), so its fingerprint matches and checkpoints taken under
    faults resume cleanly against the pristine source.  `retry_sleep`
    defaults to a no-op: the backoff schedule is exercised, the suite
    does not wait for it.
    """

    def __init__(self, inner: RowSource, *, transient=None, persistent=(),
                 kill_after_reads=None, error=OSError,
                 retry_attempts: int = 4, retry_base_delay: float = 0.05,
                 retry_max_delay: float = 2.0, retry_sleep=lambda _: None):
        super().__init__(inner.edges, inner.labels,
                         num_classes=inner.num_classes, task=inner.task,
                         chunk_size=inner.chunk_size)
        self.inner = inner
        self.transient = dict(transient or {})
        self._remaining = dict(self.transient)
        self.persistent = frozenset(persistent)
        self.kill_after_reads = kill_after_reads
        self.error = error
        self.retry_attempts = int(retry_attempts)
        self.retry_base_delay = float(retry_base_delay)
        self.retry_max_delay = float(retry_max_delay)
        self.retry_sleep = retry_sleep
        self.reads = 0          # completed logical reads
        self.attempts = 0       # every call, including failed ones

    def _inject(self) -> None:
        self.attempts += 1
        idx = self.reads
        if (self.kill_after_reads is not None
                and idx >= self.kill_after_reads):
            sigkill_self()
        if idx in self.persistent:
            raise self.error(f"injected persistent fault at read {idx}")
        if self._remaining.get(idx, 0) > 0:
            self._remaining[idx] -= 1
            raise self.error(
                f"injected transient fault at read {idx} "
                f"({self._remaining[idx]} left)")

    def bins_block(self, lo: int, hi: int):
        self._inject()
        out = self.inner.bins_block(lo, hi)
        self.reads += 1
        return out

    def bins_take(self, idx):
        self._inject()
        out = self.inner.bins_take(idx)
        self.reads += 1
        return out


# ---------------------------------------------------------------------------
# Kill hooks (subprocess-only — they SIGKILL the calling process)
# ---------------------------------------------------------------------------

def arm_kill_after_snapshots(nth: int = 1) -> None:
    """SIGKILL right after the `nth` level snapshot lands on disk —
    the kill-at-level scenario: the snapshot is complete, the levels
    after it are lost and must be replayed on resume."""
    count = [0]

    def hook(depth, path):
        count[0] += 1
        if count[0] >= nth:
            sigkill_self()
    checkpoint.POST_SNAPSHOT_HOOK[0] = hook


def arm_kill_mid_replace(nth: int = 1, match: str = "") -> None:
    """SIGKILL between an atomic write's tmp flush and its `os.replace`
    — the worst mid-checkpoint (or mid-`PackedForest.save`) window: a
    naive writer would have clobbered the target by now.  `match`
    restricts the kill to paths containing it; `nth` counts matching
    writes."""
    count = [0]

    def hook(final_path, tmp_path):
        if match and match not in os.fspath(final_path):
            return
        count[0] += 1
        if count[0] >= nth:
            sigkill_self()
    atomicio.PRE_REPLACE_HOOK[0] = hook


def disarm() -> None:
    """Clear every armed hook (harmless if none are armed)."""
    checkpoint.POST_SNAPSHOT_HOOK[0] = None
    atomicio.PRE_REPLACE_HOOK[0] = None
