"""Test-support utilities shipped with the package (not test code).

`repro.testing.faults` is the fault-injection harness for the
robustness suite and the checkpointed benchmarks (DESIGN.md §9).
"""
