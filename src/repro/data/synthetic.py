"""Synthetic datasets.

Tabular families follow the paper's §4 artificial benchmark (P. Geurts,
Guillame-Bert, Teytaud 2018: xor, majority, needle ground truths with
informative + useless variables), used by benchmarks/fig1 & fig2 and tests.

The LM side provides an infinite deterministic token stream (a mixed
n-gram/noise source) for the end-to-end training example — self-contained,
no external corpora.
"""
from __future__ import annotations

import numpy as np

from repro.core.dataset import TabularDataset, from_numpy


def make_tabular(family: str, n: int, num_informative: int = 8,
                 num_useless: int = 8, num_categorical: int = 0,
                 seed: int = 0) -> TabularDataset:
    """family: xor | majority | needle | linear."""
    rng = np.random.default_rng(seed)
    m = num_informative + num_useless
    num = rng.normal(size=(n, m)).astype(np.float32)
    inf = num[:, :num_informative]
    if family == "xor":
        y = ((inf > 0).sum(1) % 2).astype(np.int32)
    elif family == "majority":
        y = ((inf > 0).sum(1) > num_informative / 2).astype(np.int32)
    elif family == "needle":
        # highly imbalanced: positive iff all informative features positive
        y = ((inf > 0).all(1)).astype(np.int32)
    elif family == "linear":
        w = rng.normal(size=num_informative)
        y = (inf @ w > 0).astype(np.int32)
    else:
        raise ValueError(family)
    cat = None
    arities = None
    if num_categorical:
        # categorical recoding of informative dims (Leo-style high arity mix)
        arities = [int(a) for a in
                   rng.integers(2, 32, size=num_categorical)]
        cat = np.stack([rng.integers(0, a, size=n) for a in arities], axis=1)
        flip = (cat[:, 0] % 2).astype(np.int32)
        y = np.where(rng.random(n) < 0.25, y ^ flip, y).astype(np.int32)
    return from_numpy(num, cat, y, arities)


def train_test_split(ds: TabularDataset, test_frac: float = 0.25, seed: int = 1):
    rng = np.random.default_rng(seed)
    n = ds.n
    perm = rng.permutation(n)
    cut = int(n * (1 - test_frac))
    tr, te = perm[:cut], perm[cut:]

    def take(idx):
        return from_numpy(np.asarray(ds.num)[idx], np.asarray(ds.cat)[idx],
                          np.asarray(ds.labels)[idx], ds.arities, ds.task)

    return take(tr), take(te)


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

class TokenStream:
    """Deterministic synthetic LM data: a 2-gram Markov source over `vocab`
    tokens with a learnable structure (so loss visibly decreases)."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq, self.batch = vocab_size, seq_len, batch
        rng = np.random.default_rng(seed)
        k = min(vocab_size, 256)
        self._succ = rng.integers(0, vocab_size, size=(k, 4))
        self._k = k
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self):
        rng = np.random.default_rng(1000 + self._step)
        self._step += 1
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        for t in range(1, self.seq + 1):
            prev = toks[:, t - 1] % self._k
            choice = rng.integers(0, 4, size=self.batch)
            nxt = self._succ[prev, choice]
            noise = rng.integers(0, self.vocab, size=self.batch)
            use_noise = rng.random(self.batch) < 0.1
            toks[:, t] = np.where(use_noise, noise, nxt)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
