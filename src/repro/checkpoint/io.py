"""Checkpointing: flat .npz of the state pytree + sharding-aware restore.

Keys are "/"-joined pytree paths.  On restore, arrays are device_put with
the current mesh's param specs so a checkpoint written on one topology can
be loaded on another (single-host resharding; multi-host would use a
tensorstore-backed writer, same key scheme).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, state) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(state))


def restore(path: str, like, shardings: Optional[object] = None):
    """Restore into the structure of `like` (a pytree of arrays/specs)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    for (path_k, leaf), sh in zip(leaves, flat_sh):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
