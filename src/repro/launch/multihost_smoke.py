"""Multi-host smoke run: jax.distributed over N local processes (ROADMAP).

Boots a real `jax.distributed` cluster out of N co-located processes (each
with forced host devices) and trains a tiny `split_mode="hist"` forest
through the SAME `build_forest` + `ShardedHistNumeric` path the
single-process mesh tests exercise, asserting equality with the
single-process (local-engine) result.

Two modes, picked automatically:

  * ``global``  — the mesh spans ALL processes' devices and the engine's
    psum crosses process boundaries.  This is the true multi-host path;
    it requires a backend with cross-process collectives (TPU, GPU).
  * ``local-mesh`` — the CPU backend in current jax releases rejects
    cross-process computations ("Multiprocess computations aren't
    implemented on the CPU backend"), so each process falls back to a
    mesh over its OWN devices.  The smoke still proves the parts a CPU
    box can prove: the distributed service boots and every process's
    sharded-hist forest is bit-identical to the local reference and to
    every other process (fingerprints compared by the launcher).

Run:  python -m repro.launch.multihost_smoke [--nproc N]
Test: tests/test_multihost_smoke.py (-m slow).

Each worker prints ``MULTIHOST-SMOKE-OK mode=<mode> pid=<i> fp=<sha1>``;
the launcher asserts N OKs and identical fingerprints.
"""
from __future__ import annotations

import hashlib
import os
import subprocess
import sys

_PORT = int(os.environ.get("MULTIHOST_SMOKE_PORT", "12731"))
_DEVS_PER_PROC = 4


def _forest_fingerprint(forest) -> str:
    """Order-stable digest of every tree's flat arrays."""
    import numpy as np
    h = hashlib.sha1()
    for t in forest.trees:
        for name in ("feature", "threshold", "is_cat", "cat_mask",
                     "children", "value", "n_node", "gain", "depth"):
            h.update(np.ascontiguousarray(getattr(t, name)).tobytes())
    return h.hexdigest()


def _train(mesh) -> tuple[str, object]:
    """(fingerprint of the sharded-hist forest, local reference forest)."""
    import numpy as np

    from repro.core import tree as tree_lib
    from repro.core.dataset import from_numpy
    from repro.core.forest import RandomForest
    from repro.core.level.sharded import ShardedHistNumeric

    rng = np.random.default_rng(7)
    n = 512
    num = rng.normal(size=(n, 8)).astype(np.float32)
    y = ((num[:, 0] + num[:, 1] * num[:, 2]) > 0).astype(np.int32)
    ds = from_numpy(num, None, y)
    p = tree_lib.TreeParams(max_depth=3, leaf_pad=8, split_mode="hist",
                            num_bins=16)
    local = RandomForest(p, num_trees=2, seed=11, tree_batch=2).fit(ds)
    eng = ShardedHistNumeric(mesh=mesh)
    dist = RandomForest(p, num_trees=2, seed=11, tree_batch=2).fit(
        ds, engine=eng)
    a, b = _forest_fingerprint(local), _forest_fingerprint(dist)
    assert a == b, "sharded-hist forest != single-process local forest"
    return a, dist


def worker(pid: int, nproc: int) -> None:
    import jax
    jax.distributed.initialize(
        coordinator_address=f"localhost:{_PORT}",
        num_processes=nproc, process_id=pid)
    assert len(jax.devices()) == nproc * _DEVS_PER_PROC, (
        len(jax.devices()), nproc)

    import numpy as np
    from jax.sharding import Mesh

    mode = "global"
    try:
        mesh = Mesh(np.asarray(jax.devices()).reshape(
            nproc, _DEVS_PER_PROC), ("data", "model"))
        fp, _ = _train(mesh)
    except Exception as e:                       # noqa: BLE001
        if "Multiprocess computations" not in str(e):
            raise
        # CPU backend: no cross-process collectives — prove the rest on a
        # process-local mesh (the launcher still checks cross-process
        # determinism through the fingerprints)
        mode = "local-mesh"
        local_devs = jax.local_devices()
        mesh = Mesh(np.asarray(local_devs).reshape(
            2, _DEVS_PER_PROC // 2), ("data", "model"))
        fp, _ = _train(mesh)
    print(f"MULTIHOST-SMOKE-OK mode={mode} pid={pid} fp={fp}", flush=True)


def main(nproc: int = 2, timeout: float = 900.0) -> dict:
    """Spawn the workers, collect and validate their output."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count="
                         f"{_DEVS_PER_PROC}")
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.multihost_smoke",
         "--worker", str(i), str(nproc)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env) for i in range(nproc)]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            outs.append(out)
            assert p.returncode == 0, out[-3000:]
    finally:
        # a failed/timed-out worker must not orphan its peers: they sit in
        # jax.distributed.initialize holding the coordinator port, which
        # would wedge every later run against the same port
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
    oks = [line for out in outs for line in out.splitlines()
           if line.startswith("MULTIHOST-SMOKE-OK")]
    assert len(oks) == nproc, outs
    fps = {line.split("fp=")[1] for line in oks}
    assert len(fps) == 1, f"processes disagree: {oks}"
    mode = oks[0].split("mode=")[1].split()[0]
    print(f"multihost smoke: {nproc} processes OK, mode={mode}, "
          f"fingerprint {fps.pop()[:12]}")
    return {"nproc": nproc, "mode": mode}


if __name__ == "__main__":
    if "--worker" in sys.argv:
        i = sys.argv.index("--worker")
        worker(int(sys.argv[i + 1]), int(sys.argv[i + 2]))
    else:
        n = 2
        if "--nproc" in sys.argv:
            n = int(sys.argv[sys.argv.index("--nproc") + 1])
        main(n)
