"""Serving driver: prefill + batched decode for a selected architecture.

CPU-sized by default (reduced config); the production path is exercised
shape-for-shape by launch/dryrun.py (decode_32k / long_500k lower
serve.decode_step on the pod meshes).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import transformer
from repro.serve import engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen_len
    max_seq = P + G

    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    else:
        prompts = jax.random.normal(key, (B, P, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, x: engine.prefill_step(p, x, cfg))(params, prompts)
    # pad caches to max_seq along the cache-seq dim (attn caches only)
    caches = jax.tree_util.tree_map(
        lambda c: jnp.concatenate(
            [c, jnp.zeros(c.shape[:2] + (G,) + c.shape[3:], c.dtype)], axis=2)
        if c.ndim >= 4 and c.shape[2] == P else c, caches)
    print(f"prefill {B}x{P}: {time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t, l: transformer.decode_step(p, c, t, l, cfg))
    lens = jnp.full((B,), P, jnp.int32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    t0 = time.time()
    generated = [tok]
    for _ in range(G - 1):
        if cfg.input_mode != "tokens":
            step_in = jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
        else:
            step_in = generated[-1]
        lg, caches = decode(params, caches, step_in, lens)
        lens = lens + 1
        generated.append(jnp.argmax(lg[:, -1], -1)[:, None])
    dt = time.time() - t0
    print(f"decode {G-1} steps x {B} seqs: {dt:.2f}s "
          f"({(G-1)*B/max(dt,1e-9):.1f} tok/s)")
    out = jnp.concatenate(generated, axis=1)
    print("sample tokens:", out[0][:10].tolist())


if __name__ == "__main__":
    main()
