"""ShapeDtypeStruct stand-ins + shardings for every (arch × input-shape)
workload — the dry-run never allocates real arrays (assignment step 2).

For each shape kind:
  train_4k    -> train_step(state, batch)
  prefill_32k -> prefill_step(params, inputs)
  decode_*    -> decode_step(params, caches, inputs, cache_len)

Shardings follow train/sharding.py logical rules; decode_32k uses the
"batch-over-data" cache recipe, long_500k the "seq-over-data" recipe.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ArchConfig
from repro.models import transformer
from repro.optim import adamw
from repro.serve import engine
from repro.train import sharding as shd, step as train_step_lib


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _batch_axes(mesh, rules):
    return shd.logical_spec(("batch",), mesh, rules)[0]


def moments_dtype_for(cfg: ArchConfig) -> str:
    """bf16 Adam moments for models whose f32 moments would blow the pod
    HBM budget (jamba-398b, dbrx-132b); f32 elsewhere.  See DESIGN.md."""
    big = cfg.d_model * cfg.d_ff * cfg.num_layers
    if cfg.num_experts:
        big *= cfg.num_experts
    return "bfloat16" if big > 2**40 else "float32"


def make_train_cfg(cfg: ArchConfig, unroll=True,
                   microbatches: int = 1,
                   remat: str = "full") -> train_step_lib.TrainConfig:
    return train_step_lib.TrainConfig(
        optimizer=adamw.AdamWConfig(moments_dtype=moments_dtype_for(cfg)),
        unroll=unroll, ce_unroll=bool(unroll), remat=remat,
        # accounting passes (unroll != False) keep mb=1: identical math over
        # the full batch, so FLOP/collective totals are exact; the memory
        # pass uses the real microbatch schedule.
        microbatches=1 if unroll else microbatches)


# ---------------------------------------------------------------------------
# Abstract state / batch / cache builders
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))


def abstract_state(cfg: ArchConfig, tcfg):
    return jax.eval_shape(
        lambda: train_step_lib.init_train_state(jax.random.PRNGKey(0), cfg, tcfg))


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: transformer.init_cache(cfg, batch, max_seq))


def batch_struct(cfg: ArchConfig, shape_name: str, mesh, rules):
    s = INPUT_SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]
    bspec = shd.logical_spec(("batch", "seq"), mesh, rules)
    if cfg.input_mode == "tokens":
        inputs = _sds((B, S), jnp.int32, _ns(mesh, bspec))
    else:
        inputs = _sds((B, S, cfg.d_model), jnp.bfloat16,
                      _ns(mesh, shd.logical_spec(("batch", "seq", None),
                                                 mesh, rules)))
    labels = _sds((B, S), jnp.int32, _ns(mesh, bspec))
    return {"inputs": inputs, "labels": labels}


_CACHE_AXES = {
    "k":    (None, "batch", "cache_seq", "kv_heads", None),
    "v":    (None, "batch", "cache_seq", "kv_heads", None),
    "conv": (None, "batch", None, "d_inner"),
    "h":    (None, "batch", "d_inner", "state"),
    "x_tm": (None, "batch", None),
    "x_cm": (None, "batch", None),
    "S":    (None, "batch", "heads", None, None),
}


def cache_shardings(cfg: ArchConfig, batch: int, max_seq: int, mesh, rules):
    shapes = abstract_cache(cfg, batch, max_seq)

    def walk(path, leaf):
        name = path[-1].key
        axes = _CACHE_AXES[name]
        return _ns(mesh, shd.logical_spec(axes, mesh, rules, leaf.shape))

    return jax.tree_util.tree_map_with_path(walk, shapes)


def with_shardings(structs, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), structs, shardings)


# ---------------------------------------------------------------------------
# Lowerables: (fn, example_args, in_shardings-embedded) per workload
# ---------------------------------------------------------------------------

def train_lowerable(cfg: ArchConfig, shape_name: str, mesh, overrides=None,
                    unroll=True):
    overrides = dict(overrides or {})
    mb = int(overrides.pop("microbatches", 1) or 1)
    remat = overrides.pop("remat", "full") or "full"
    rules = shd.make_rules(mesh, overrides)
    tcfg = make_train_cfg(cfg, unroll=unroll, microbatches=mb, remat=remat)
    state_struct = abstract_state(cfg, tcfg)
    pspecs = shd.tree_param_specs(state_struct["params"], mesh, rules)
    ospecs = {
        "mu": shd.tree_param_specs(state_struct["opt"]["mu"], mesh, rules),
        "nu": shd.tree_param_specs(state_struct["opt"]["nu"], mesh, rules),
        "step": _ns(mesh, P()),
    }
    state = with_shardings(state_struct, {"params": pspecs, "opt": ospecs})
    batch = batch_struct(cfg, shape_name, mesh, rules)
    raw_step = train_step_lib.make_train_step(cfg, tcfg)

    def step(state, batch):
        with shd.use_mesh_rules(mesh, overrides):
            return raw_step(state, batch)

    out_sh = ({"params": pspecs, "opt": ospecs},
              {k: _ns(mesh, P()) for k in ("loss", "ce", "aux", "grad_norm", "lr")})
    return step, (state, batch), out_sh, (0,)   # donate the train state


def prefill_lowerable(cfg: ArchConfig, shape_name: str, mesh, overrides=None,
                      unroll=True):
    rules = shd.make_rules(mesh, overrides)
    pstruct = abstract_params(cfg)
    pspecs = shd.tree_param_specs(pstruct, mesh, rules)
    params = with_shardings(pstruct, pspecs)
    batch = batch_struct(cfg, shape_name, mesh, rules)
    s = INPUT_SHAPES[shape_name]
    # returned caches: batch over data, SEQ over model (kv_heads rarely
    # divide the model axis) — the layout decode_32k consumes.
    crules = shd.make_rules(mesh, dict(shd.DECODE_OVERRIDES,
                                       **(overrides or {})))
    cspecs = cache_shardings(cfg, s["global_batch"], s["seq_len"], mesh,
                             crules)

    def step(params, inputs):
        with shd.use_mesh_rules(mesh, overrides):
            return engine.prefill_step(params, inputs, cfg, unroll=unroll)

    return step, (params, batch["inputs"]), (None, cspecs), ()


def decode_lowerable(cfg: ArchConfig, shape_name: str, mesh, overrides=None,
                     unroll=True):
    s = INPUT_SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]
    base = (shd.LONG_CONTEXT_OVERRIDES if shape_name == "long_500k"
            else shd.DECODE_OVERRIDES)
    overrides = dict(base, **(overrides or {}))
    rules = shd.make_rules(mesh, overrides)
    pstruct = abstract_params(cfg)
    pspecs = shd.tree_param_specs(pstruct, mesh, rules)
    params = with_shardings(pstruct, pspecs)
    cstruct = abstract_cache(cfg, B, S)
    cspecs = cache_shardings(cfg, B, S, mesh, rules)
    caches = with_shardings(cstruct, cspecs)
    bspec = shd.logical_spec(("batch",), mesh, rules)
    if cfg.input_mode == "tokens":
        inputs = _sds((B, 1), jnp.int32,
                      _ns(mesh, shd.logical_spec(("batch", None), mesh, rules)))
    else:
        inputs = _sds((B, 1, cfg.d_model), jnp.bfloat16,
                      _ns(mesh, shd.logical_spec(("batch", None, None), mesh, rules)))
    cache_len = _sds((B,), jnp.int32, _ns(mesh, bspec))

    def step(params, caches, inputs, cache_len):
        with shd.use_mesh_rules(mesh, overrides):
            return engine.decode_step(params, caches, inputs, cache_len, cfg,
                                      unroll=unroll)

    out_sh = (None, cspecs)   # keep cache sharding stable step-to-step
    return step, (params, caches, inputs, cache_len), out_sh, (1,)  # donate cache


def lowerable_for(cfg: ArchConfig, shape_name: str, mesh, overrides=None,
                  unroll=True):
    kind = INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return train_lowerable(cfg, shape_name, mesh, overrides, unroll)
    if kind == "prefill":
        return prefill_lowerable(cfg, shape_name, mesh, overrides, unroll)
    return decode_lowerable(cfg, shape_name, mesh, overrides, unroll)


def skip_reason(cfg: ArchConfig, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("full quadratic attention, no sliding-window variant: "
                "long_500k requires sub-quadratic attention (DESIGN.md §5)")
    return None
