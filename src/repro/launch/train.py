"""End-to-end LM training driver.

On real hardware this runs with the production mesh; on CPU (CI/dev) pass
--smoke to train the reduced config on a 1-device mesh.  Used by
examples/train_lm.py for the ~100M-param few-hundred-step requirement.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.data.synthetic import TokenStream
from repro.checkpoint import io as ckpt_io
from repro.models import transformer
from repro.optim import adamw
from repro.train import sharding as shd, step as train_step_lib


def train_loop(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
               seed: int = 0, log_every: int = 10, mesh=None,
               checkpoint_path: str | None = None, ce_chunks: int = 4):
    tcfg = train_step_lib.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                    total_steps=steps),
        ce_chunks=ce_chunks)
    key = jax.random.PRNGKey(seed)
    state = train_step_lib.init_train_state(key, cfg, tcfg)
    n_params = transformer.param_count(state["params"])
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={steps} "
          f"batch={batch} seq={seq}", flush=True)

    step_fn = train_step_lib.make_train_step(cfg, tcfg)
    if mesh is not None:
        pspecs = shd.tree_param_specs(state["params"], mesh)

        def wrapped(state, batch_):
            with shd.use_mesh_rules(mesh):
                return step_fn(state, batch_)

        step_jit = jax.jit(wrapped, donate_argnums=0)
        state = jax.device_put(state, {
            "params": pspecs,
            "opt": {"mu": shd.tree_param_specs(state["opt"]["mu"], mesh),
                    "nu": shd.tree_param_specs(state["opt"]["nu"], mesh),
                    "step": None}})
    else:
        step_jit = jax.jit(step_fn, donate_argnums=0)

    stream = TokenStream(cfg.vocab_size, seq, batch, seed)
    losses = []
    t0 = time.time()
    for i, raw in zip(range(steps), stream):
        batch_ = {"inputs": jnp.asarray(raw["inputs"]),
                  "labels": jnp.asarray(raw["labels"])}
        state, m = step_jit(state, batch_)
        losses.append(float(m["ce"]))
        if i % log_every == 0 or i == steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d}  ce={losses[-1]:.4f}  "
                  f"aux={float(m['aux']):.4f}  gnorm={float(m['grad_norm']):.2f}  "
                  f"lr={float(m['lr']):.2e}  {dt:.1f}s", flush=True)
    if checkpoint_path:
        ckpt_io.save(checkpoint_path, state)
        print(f"checkpoint -> {checkpoint_path}", flush=True)
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, lr=args.lr,
                           checkpoint_path=args.checkpoint)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"ce first10={first:.4f} last10={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
