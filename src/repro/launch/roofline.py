"""Roofline-term extraction from compiled dry-run artifacts (assignment
§ROOFLINE ANALYSIS).

  compute term    = HLO_FLOPs_global / (chips × peak_FLOP/s)
  memory term     = HLO_bytes_global / (chips × HBM_bw)
  collective term = collective_bytes_global / (chips × link_bw)

`compiled.cost_analysis()` describes the per-device partitioned module, so
global = per-device × chips and the per-chip terms reduce to
per-device / peak.  Collective bytes are NOT in cost_analysis: we parse the
post-SPMD optimized HLO (`compiled.as_text()`) and sum result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with an all-reduce counted 2× (ring RS+AG).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment block).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\(")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-kind result bytes (per device) from post-SPMD HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_s)
        counts[kind] += 1
    wire = sum(b * (2 if k == "all-reduce" else 1) for k, b in out.items())
    return {"bytes_by_kind": out, "counts": counts, "wire_bytes": wire}


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    collectives: dict

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_dev": self.flops_per_device,
            "hlo_bytes_per_dev": self.bytes_per_device,
            "coll_bytes_per_dev": self.collective_bytes_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collective_counts": self.collectives["counts"],
            "collective_bytes": self.collectives["bytes_by_kind"],
        }


def model_flops(cfg, shape_name: str, n_active: int) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    from repro.configs.base import INPUT_SHAPES
    s = INPUT_SHAPES[shape_name]
    if s["kind"] == "train":
        tokens = s["global_batch"] * s["seq_len"]
        return 6.0 * n_active * tokens
    if s["kind"] == "prefill":
        tokens = s["global_batch"] * s["seq_len"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * s["global_batch"]          # decode: 1 token/seq


def _cost(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):                          # older jax returns [dict]
        cost = cost[0]
    return cost


def extract(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops_global: float) -> RooflineTerms:
    cost = _cost(compiled)
    coll = parse_collectives(compiled.as_text())
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(coll["wire_bytes"]),
        model_flops_global=model_flops_global,
        collectives=coll)


def extract_extrapolated(c1, c2, u1: int, u2: int, nb: int, *, arch: str,
                         shape: str, mesh_name: str, chips: int,
                         model_flops_global: float) -> RooflineTerms:
    """Totals from two loop-form compiles with unroll factors u1 < u2.

    cost_analysis counts a scan body once, so f(u) = outside + u·block for
    every additive metric; total = outside + nb·block.  Exact when the nb
    blocks are structurally identical (they are: stacked layer params).
    """
    def lin(a, b):
        block = (b - a) / (u2 - u1)
        return max(a + (nb - u1) * block, 0.0)

    k1, k2 = _cost(c1), _cost(c2)
    coll1 = parse_collectives(c1.as_text())
    coll2 = parse_collectives(c2.as_text())
    coll = {
        "bytes_by_kind": {k: int(lin(coll1["bytes_by_kind"][k],
                                     coll2["bytes_by_kind"][k]))
                          for k in coll1["bytes_by_kind"]},
        "counts": {k: int(round(lin(coll1["counts"][k], coll2["counts"][k])))
                   for k in coll1["counts"]},
    }
    coll["wire_bytes"] = sum(b * (2 if k == "all-reduce" else 1)
                             for k, b in coll["bytes_by_kind"].items())
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=lin(float(k1.get("flops", 0.0)),
                             float(k2.get("flops", 0.0))),
        bytes_per_device=lin(float(k1.get("bytes accessed", 0.0)),
                             float(k2.get("bytes accessed", 0.0))),
        collective_bytes_per_device=float(coll["wire_bytes"]),
        model_flops_global=model_flops_global,
        collectives=coll)
