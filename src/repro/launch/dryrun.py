import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: the dry-run (and
# ONLY the dry-run) needs 512 placeholder devices for the production mesh.

"""Multi-pod dry-run (assignment MULTI-POD DRY-RUN step 3).
(note: no `from __future__` here — the XLA_FLAGS lines must stay first)

For every (architecture × input shape): build the step function + abstract
inputs (launch/specs.py), `jit(...).lower(...)` with the production
shardings, `.compile()`, and record memory_analysis / cost_analysis /
roofline terms.  Runs for the 16×16 single-pod mesh and the (2,16,16)
multi-pod mesh.  Any sharding mismatch / compile OOM / unsupported
collective here is a bug in the system.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--multi-pod] [--drf] [--out results.jsonl] [--hlo-dir DIR]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES, get_arch, list_archs
from repro.launch import mesh as mesh_lib, roofline, specs
from repro.models import transformer


def _mem_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0) + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0) - out.get("alias_size_in_bytes", 0))
    return out


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            overrides=None, hlo_dir=None, verbose=True,
            accounting: bool = True) -> dict:
    cfg = get_arch(arch)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    reason = specs.skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        if verbose:
            print(f"SKIP {arch:23s} {shape:12s} {rec['mesh']:8s} {reason}",
                  flush=True)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        # PASS 1 — memory: scan (loop) form.  XLA-CPU's scheduler inflates
        # liveness ~10x on fully unrolled graphs (measured: qwen3 train_4k
        # 98 GiB unrolled vs 11.1 GiB as a loop, same computation); the loop
        # form is the realistic capacity number that must fit 16 GB/chip.
        fn, args, out_sh, donate = specs.lowerable_for(
            cfg, shape, mesh, overrides, unroll=False)
        kw = {"donate_argnums": donate} if donate else {}
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        compiled_mem = jax.jit(fn, **kw).lower(*args).compile()
        mem = _mem_summary(compiled_mem)
        t_mem = time.time() - t0
        del compiled_mem

        terms = None
        if accounting:
            # PASS 2 — accounting by linear extrapolation: a lax.scan body
            # is counted ONCE by cost_analysis regardless of trip count, so
            # compile the loop with unroll=1 and unroll=u2 (both cheap loop
            # forms; the blocks are identical) and solve
            #   f(u) = outside + u*block  =>  total = outside + nb*block.
            # Full unrolling gives the same totals but is 10-30x slower to
            # compile for the deep models (jamba: >30 min vs ~2 min).
            t0 = time.time()
            nb = cfg.num_blocks
            u2 = 2 if nb % 2 == 0 else (3 if nb % 3 == 0 else None)
            compiled_u = {}
            for u in ([1, u2] if u2 else [1]):
                fn, args, out_sh, donate = specs.lowerable_for(
                    cfg, shape, mesh, overrides, unroll=u)
                kw = {"donate_argnums": donate} if donate else {}
                if out_sh is not None:
                    kw["out_shardings"] = out_sh
                compiled_u[u] = jax.jit(fn, **kw).lower(*args).compile()
            t_acct = time.time() - t0
        else:
            compiled_u, t_acct = {}, 0.0

        n_active = transformer.active_param_count(
            specs.abstract_params(cfg), cfg)
        mf = roofline.model_flops(cfg, shape, n_active)
        rec.update(status="ok", mem_compile_s=round(t_mem, 1),
                   acct_compile_s=round(t_acct, 1), memory=mem,
                   n_active_params=int(n_active))
        if compiled_u:
            nb = cfg.num_blocks
            if u2:
                terms = roofline.extract_extrapolated(
                    compiled_u[1], compiled_u[u2], 1, u2, nb,
                    arch=arch, shape=shape, mesh_name=rec["mesh"],
                    chips=chips, model_flops_global=mf)
            else:
                terms = roofline.extract(
                    compiled_u[1], arch=arch, shape=shape,
                    mesh_name=rec["mesh"], chips=chips,
                    model_flops_global=mf)
            rec.update(roofline=terms.row())
        if hlo_dir and compiled_u:
            import pathlib
            p = pathlib.Path(hlo_dir)
            p.mkdir(parents=True, exist_ok=True)
            (p / f"{arch}.{shape}.{rec['mesh']}.hlo.txt").write_text(
                compiled_u[1].as_text())
        if verbose:
            msg = (f"OK  {arch:24s} {shape:12s} {rec['mesh']:8s} "
                   f"mem/dev={mem['total_bytes_per_device']/2**30:7.2f}GiB ")
            if terms is not None:
                r = terms.row()
                msg += (f"compute={r['compute_s']*1e3:9.3f}ms "
                        f"memory={r['memory_s']*1e3:9.3f}ms "
                        f"coll={r['collective_s']*1e3:9.3f}ms "
                        f"dom={r['dominant']:10s} "
                        f"useful={r['useful_flops_ratio']:.3f}")
            print(msg, flush=True)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"ERR {arch:24s} {shape:12s} {rec['mesh']:8s} {e}", flush=True)
    return rec


def run_drf(*, multi_pod: bool = False, verbose=True,
            n=2**22, m=128, num_leaves=255, backend="segment",
            replicated_rows: bool = False, tag: str = "") -> dict:
    """Dry-run the paper's own workload: one DRF supersplit level on the
    production mesh (features over 'model', presorted rows over 'data').

    `replicated_rows=True` = the paper's actual memory layout (§2.3: the
    class list is replicated on every splitter), so no resharding
    all-gather of (leaf_of, labels, w) is needed at the level boundary.
    """
    import jax.numpy as jnp
    from repro.core import distributed

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": "drf-level" + (f"-{tag}" if tag else ""),
           "shape": f"n{n}_m{m}_L{num_leaves}",
           "mesh": "2x16x16" if multi_pod else "16x16", "backend": backend}
    try:
        step = distributed.drf_level_step_fn(
            mesh, num_leaves=num_leaves, num_classes=2, backend=backend,
            row_axis="data")
        from jax.sharding import NamedSharding, PartitionSpec as P
        fm = NamedSharding(mesh, P("model", "data"))
        fr = NamedSharding(mesh, P() if replicated_rows else P("data"))
        args = (
            jax.ShapeDtypeStruct((m, n), jnp.float32, sharding=fm),   # sorted_vals
            jax.ShapeDtypeStruct((m, n), jnp.int32, sharding=fm),     # sorted_idx
            jax.ShapeDtypeStruct((n,), jnp.int32, sharding=fr),       # leaf_of
            jax.ShapeDtypeStruct((n,), jnp.int32, sharding=fr),       # labels
            jax.ShapeDtypeStruct((n,), jnp.float32, sharding=fr),     # w
            jax.ShapeDtypeStruct((m, num_leaves + 1), jnp.bool_,
                                 sharding=NamedSharding(mesh, P("model"))),
        )
        t0 = time.time()
        lowered = jax.jit(step).lower(*args)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        mem = _mem_summary(compiled)
        # "model flops": one pass histogram update ~ 8 flops/row/feature
        mf = 8.0 * float(n) * m
        terms = roofline.extract(compiled, arch="drf-level",
                                 shape=rec["shape"], mesh_name=rec["mesh"],
                                 chips=chips, model_flops_global=mf)
        rec.update(status="ok", memory=mem, roofline=terms.row())
        if verbose:
            r = terms.row()
            print(f"OK  drf-level {rec['shape']} {rec['mesh']} "
                  f"mem/dev={mem['total_bytes_per_device']/2**30:.3f}GiB "
                  f"compute={r['compute_s']*1e3:.3f}ms "
                  f"memory={r['memory_s']*1e3:.3f}ms "
                  f"coll={r['collective_s']*1e3:.3f}ms dom={r['dominant']}",
                  flush=True)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"ERR drf-level {e}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--drf", action="store_true", help="also dry-run DRF")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                # multi-pod pass proves the "pod" axis shards + fits;
                # the roofline accounting table is single-pod only.
                records.append(run_one(a, s, multi_pod=mp,
                                       hlo_dir=args.hlo_dir,
                                       accounting=not mp))
        if args.drf:
            records.append(run_drf(multi_pod=mp))

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"\n{ok} ok / {sk} skipped / {err} errors "
          f"of {len(records)} combinations")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
