"""Production mesh factory (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (TPU v5e pod).  Multi-pod:
(2, 16, 16) = 512 chips with a leading "pod" axis (DP across pods; the
"pod" axis shards the global batch together with "data").
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x has neither the kwarg nor
    # jax.sharding.AxisType — fall back to the plain call there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices, for distributed-engine tests."""
    return _make_mesh((data, model), ("data", "model"))
