"""Production mesh factory (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (TPU v5e pod).  Multi-pod:
(2, 16, 16) = 512 chips with a leading "pod" axis (DP across pods; the
"pod" axis shards the global batch together with "data").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices, for distributed-engine tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))
