"""RWKV-6 "Finch" block: data-dependent-decay linear attention (time-mix)
+ squared-ReLU channel-mix.  [arXiv:2404.05892]

Time-mix recurrence per head (head size N):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t · (diag(u) k_t^T v_t + S_{t-1})

with w_t = exp(-exp(ww_t)) a per-channel, DATA-DEPENDENT decay (the Finch
contribution) produced by a low-rank MLP of the token-shifted input.

TPU-native chunked evaluation: within a chunk of length c the pairwise
decay factors exp(L_{t-1} - L_s) (s < t) have non-positive exponents, so
the closed form is overflow-safe for ANY decay rate; across chunks a
lax.scan carries S.  Per-chunk cost is two small matmul-like einsums —
MXU work — instead of S sequential state updates.

Decode is the recurrence verbatim: O(1) state per token, which is why
rwkv6-7b is long_500k-eligible.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.train import sharding as shd

LORA_DIM = 64


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_timemix(key, cfg):
    D = cfg.d_model
    dt = _dt(cfg)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(D)
    return {
        "mu": jax.random.uniform(ks[0], (5, D), jnp.float32),   # r,k,v,g,w lerps
        "w0": jnp.full((D,), -6.0, jnp.float32),                # slow decay init
        "wA": jax.random.normal(ks[1], (D, LORA_DIM), jnp.float32) * s,
        "wB": jax.random.normal(ks[2], (LORA_DIM, D), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[3], (D,), jnp.float32) * 0.5,
        "wr": jax.random.normal(ks[4], (D, D), dt) * s,
        "wk_r": jax.random.normal(ks[5], (D, D), dt) * s,
        "wv_r": jax.random.normal(ks[6], (D, D), dt) * s,
        "wg": jax.random.normal(ks[7], (D, D), dt) * s,
        "wo_r": jax.random.normal(jax.random.fold_in(key, 9), (D, D), dt)
                * (s / math.sqrt(2 * cfg.num_layers)),
        "ln_x": jnp.ones((D,), jnp.float32),                    # per-head group norm
    }


def init_channelmix(key, cfg):
    D, F = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_c": jax.random.uniform(k1, (2, D), jnp.float32),    # k,r lerps
        "ck": jax.random.normal(k1, (D, F), dt) / math.sqrt(D),
        "cv": jax.random.normal(k2, (F, D), dt) / math.sqrt(F),
        "cr": jax.random.normal(k3, (D, D), dt) / math.sqrt(D),
    }


def _shift(x, x_prev):
    """Token shift: x_{t-1}, with x_prev (B, D) for the first position."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix_inputs(p, x, xx):
    mu = p["mu"][:, None, None, :]                              # (5,1,1,D)
    lerp = x[None] + (xx - x)[None] * mu                        # (5,B,S,D)
    xr, xk, xv, xg, xw = lerp
    r = jnp.einsum("bsd,de->bse", xr.astype(p["wr"].dtype), p["wr"])
    k = jnp.einsum("bsd,de->bse", xk.astype(p["wr"].dtype), p["wk_r"])
    v = jnp.einsum("bsd,de->bse", xv.astype(p["wr"].dtype), p["wv_r"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg.astype(p["wr"].dtype), p["wg"]))
    ww = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    logw = -jnp.exp(ww)                                         # log decay <= 0
    return r, k, v, g, logw


def _group_norm(x, scale, H, eps=64e-5):
    """Per-head layer norm over head channels (RWKV ln_x)."""
    B, S, D = x.shape
    xh = x.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, D) * scale).astype(x.dtype)


def timemix(p, x, cfg, state=None, chunk: int = 32):
    """Full-sequence time-mix.  x: (B,S,D) -> (out, (x_last, S_state))."""
    B, S, D = x.shape
    H = cfg.num_heads
    N = D // H
    x_prev = jnp.zeros((B, D), x.dtype) if state is None else state[0]
    S0 = jnp.zeros((B, H, N, N), jnp.float32) if state is None else state[1]

    xx = _shift(x, x_prev)
    r, k, v, g, logw = _mix_inputs(p, x, xx)
    pad = (-S) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    T = r.shape[1]
    nc = T // chunk

    def resh(a, dtype=None):
        a = a.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
        return a if dtype is None else a.astype(dtype)

    rs, ks, vs = resh(r, jnp.float32), resh(k, jnp.float32), resh(v, jnp.float32)
    lw = resh(logw)
    u = p["u"].reshape(H, N)

    @jax.checkpoint        # save one state per chunk, remat intra-chunk work
    def body(S0, inp):
        rc, kc, vc, lwc = inp                                   # (B,c,H,N)
        L = jnp.cumsum(lwc, axis=1)                             # inclusive
        Lx = L - lwc                                            # exclusive
        # inter-chunk: r_t decayed to chunk start @ carried state
        inter = jnp.einsum("bthn,bhnm->bthm", rc * jnp.exp(Lx), S0)
        # intra-chunk: pairwise decay exp(Lx[t] - L[s]) <= 1 for s < t
        dmat = jnp.exp(Lx[:, :, None] - L[:, None])             # (b,t,s,h,n)
        att = jnp.einsum("bthn,bshn,btshn->bhts", rc, kc, dmat)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        intra = jnp.einsum("bhts,bshm->bthm", att, vc)
        diag = jnp.einsum("bthn,hn,bthn->bth", rc, u, kc)
        intra = intra + diag[..., None] * vc
        out = inter + intra                                     # (b,c,h,m)
        # state: S_c = exp(L_c) * S0 + sum_s exp(L_c - L_s) k_s v_s
        Lend = L[:, -1][:, None]                                # (b,1,h,n)
        kdec = kc * jnp.exp(Lend - L)
        S1 = jnp.exp(Lend[:, 0])[..., None] * S0 \
            + jnp.einsum("bshn,bshm->bhnm", kdec, vc)
        return S1, out

    Sf, outs = jax.lax.scan(body, S0, (rs, ks, vs, lw))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T, D)[:, :S]
    out = _group_norm(out, p["ln_x"], H) * g[:, :S].astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out.astype(p["wo_r"].dtype), p["wo_r"])
    out = shd.shard(out, ("batch", "seq", None))
    return out.astype(x.dtype), (x[:, -1], Sf)


def timemix_decode(p, x1, cfg, state):
    """One-token decode.  x1: (B,1,D); state: (x_prev (B,D), S (B,H,N,N))."""
    B, _, D = x1.shape
    H, N = cfg.num_heads, D // cfg.num_heads
    x_prev, S0 = state
    xx = x_prev[:, None]
    r, k, v, g, logw = _mix_inputs(p, x1, xx)
    rh = r.reshape(B, H, N).astype(jnp.float32)
    kh = k.reshape(B, H, N).astype(jnp.float32)
    vh = v.reshape(B, H, N).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, N))
    u = p["u"].reshape(H, N)
    kv = kh[..., :, None] * vh[..., None, :]                    # (B,H,N,N)
    o = jnp.einsum("bhn,bhnm->bhm", rh, u[None, :, :, None] * kv + S0)
    S1 = w[..., None] * S0 + kv
    out = o.reshape(B, 1, D)
    out = _group_norm(out, p["ln_x"], H) * g.astype(out.dtype)
    out = jnp.einsum("bsd,de->bse", out.astype(p["wo_r"].dtype), p["wo_r"])
    return out.astype(x1.dtype), (x1[:, -1], S1)


def channelmix(p, x, cfg, state=None):
    """Squared-ReLU channel mix.  Returns (out, x_last)."""
    B, S, D = x.shape
    x_prev = jnp.zeros((B, D), x.dtype) if state is None else state
    xx = _shift(x, x_prev)
    mu = p["mu_c"][:, None, None, :]
    xk, xr = (x[None] + (xx - x)[None] * mu)
    kk = jnp.einsum("bsd,df->bsf", xk.astype(p["ck"].dtype), p["ck"])
    kk = shd.shard(kk, ("batch", "seq", "ff"))
    vv = jnp.einsum("bsf,fd->bsd", jnp.square(jax.nn.relu(kk)), p["cv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr.astype(p["cr"].dtype), p["cr"]))
    return (rr * vv).astype(x.dtype), x[:, -1]
