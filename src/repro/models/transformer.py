"""Decoder LM assembly from ArchConfig: dense / MoE / RWKV / hybrid / audio /
VLM, with scan-over-blocks (MaxText-style stacked layer params — one traced
block regardless of depth, so 72-layer Jamba compiles as fast as 2-layer).

Three entry points:
  forward(params, inputs, cfg)                  -> logits, aux, caches
  decode_step(params, caches, inputs, lens, cfg)-> logits, new_caches
  init_params(key, cfg) / init_cache(cfg, B, S) -> pytrees

`inputs` is tokens (B,S) int32 for input_mode="tokens", or precomputed
embeddings (B,S,D) for the audio/VLM stub frontends (assignment carve-out).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, mamba as mamba_lib, moe as moe_lib, rwkv as rwkv_lib
from repro.train import sharding as shd


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block_position(key, cfg, mix: str, ffn: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = _dt(cfg)
    p = {"norm1": jnp.ones((cfg.d_model,), dt),
         "norm2": jnp.ones((cfg.d_model,), dt)}
    if mix == "attn":
        p["mixer"] = layers.init_attention(k1, cfg)
    elif mix == "mamba":
        p["mixer"] = mamba_lib.init_mamba(k1, cfg)
    elif mix == "rwkv":
        p["mixer"] = rwkv_lib.init_timemix(k1, cfg)
    else:
        raise ValueError(mix)
    if ffn == "dense":
        p["ffn"] = layers.init_mlp(k2, cfg)
    elif ffn == "moe":
        p["ffn"] = moe_lib.init_moe(k3, cfg)
    elif ffn == "channelmix":
        p["ffn"] = rwkv_lib.init_channelmix(k4, cfg)
    else:
        raise ValueError(ffn)
    return p


def init_params(key, cfg):
    nb = cfg.num_blocks
    dt = _dt(cfg)
    keys = jax.random.split(key, 3)
    params = {"final_norm": jnp.ones((cfg.d_model,), dt)}
    if cfg.input_mode == "tokens":
        params["embedding"] = (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), dt) * 0.02)
    params["lm_head"] = jax.random.normal(
        keys[1], (cfg.d_model, cfg.vocab_size), dt) / math.sqrt(cfg.d_model)

    blocks = {}
    for i, (mix, ffn) in enumerate(cfg.block_pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[2], i), nb)
        blocks[f"pos{i}"] = jax.vmap(
            lambda k: _init_block_position(k, cfg, mix, ffn))(bkeys)
    params["blocks"] = blocks
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def active_param_count(params, cfg) -> int:
    """Params touched per token (MoE experts scaled by top-k/E)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        n = leaf.size
        if name in ("we1", "we2", "we3") and cfg.num_experts:
            n = n * cfg.num_experts_per_tok // cfg.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int):
    nb = cfg.num_blocks
    dt = _dt(cfg)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    N = D // H
    cache = {}
    for i, (mix, ffn) in enumerate(cfg.block_pattern):
        c = {}
        if mix == "attn":
            c["k"] = jnp.zeros((nb, batch, max_seq, KV, hd), dt)
            c["v"] = jnp.zeros((nb, batch, max_seq, KV, hd), dt)
        elif mix == "mamba":
            DI, NS, K = (mamba_lib.d_inner(cfg), cfg.mamba_d_state,
                         cfg.mamba_conv)
            c["conv"] = jnp.zeros((nb, batch, K - 1, DI), dt)
            c["h"] = jnp.zeros((nb, batch, DI, NS), jnp.float32)
        elif mix == "rwkv":
            c["x_tm"] = jnp.zeros((nb, batch, D), dt)
            c["S"] = jnp.zeros((nb, batch, H, N, N), jnp.float32)
        if ffn == "channelmix":
            c["x_cm"] = jnp.zeros((nb, batch, D), dt)
        cache[f"pos{i}"] = c
    return cache


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, inputs, cfg, positions):
    if cfg.input_mode == "tokens":
        x = params["embedding"][inputs]          # (B,S,D) gather
    else:
        x = inputs.astype(_dt(cfg))              # precomputed embeddings (stub)
    if cfg.pos_style == "sinusoidal":
        x = x + layers.sinusoidal_emb(positions, cfg.d_model).astype(x.dtype)
    return shd.shard(x, ("batch", "res_seq", None))


def forward_hidden(params, inputs, cfg, positions=None,
                   collect_cache: bool = False, unroll=False,
                   remat: str = "none"):
    """Backbone only: returns (final hidden (B,S,D), aux_loss, caches).

    `unroll=True` unrolls the block scan (single-trip loop) so the dry-run's
    `cost_analysis()` counts every layer — lax.scan bodies are otherwise
    counted once regardless of trip count (see launch/roofline.py).

    `remat` checkpoints EACH BLOCK (backward recomputes one block at a
    time — peak activation memory is one block's transients plus the
    per-block carries, not the whole depth).
    """
    B, S = inputs.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = _embed(params, inputs, cfg, positions)

    def block_body(carry, bp):
        x, aux = carry
        caches = {}
        for i, (mix, ffn) in enumerate(cfg.block_pattern):
            pp = bp[f"pos{i}"]
            h = layers.rms_norm(x, pp["norm1"], cfg.norm_eps)
            if mix == "attn":
                mo, kv = layers.attention(pp["mixer"], h, cfg, positions)
                cch = {"k": kv[0], "v": kv[1]} if collect_cache else {}
            elif mix == "mamba":
                mo, st = mamba_lib.mamba(pp["mixer"], h, cfg)
                cch = {"conv": st[0], "h": st[1]} if collect_cache else {}
            else:  # rwkv
                mo, st = rwkv_lib.timemix(pp["mixer"], h, cfg)
                cch = {"x_tm": st[0], "S": st[1]} if collect_cache else {}
            x = x + mo
            h2 = layers.rms_norm(x, pp["norm2"], cfg.norm_eps)
            if ffn == "dense":
                f = layers.mlp(pp["ffn"], h2)
            elif ffn == "moe":
                f, al = moe_lib.moe_ffn(pp["ffn"], h2, cfg)
                aux = aux + al
            else:  # channelmix
                f, xcm = rwkv_lib.channelmix(pp["ffn"], h2, cfg)
                if collect_cache:
                    cch["x_cm"] = xcm
            x = x + f
            x = shd.shard(x, ("batch", "res_seq", None))
            caches[f"pos{i}"] = cch
        return (x, aux), caches

    body = block_body
    if remat == "full":
        body = jax.checkpoint(block_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            block_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    un = (cfg.num_blocks if unroll is True
          else (unroll if isinstance(unroll, int) and unroll else 1))
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.float32(0.0)), params["blocks"], unroll=un)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, (caches if collect_cache else None)


def project_logits(params, x, cfg):
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shd.shard(logits, ("batch", "seq", "vocab"))


def forward(params, inputs, cfg, positions=None, collect_cache: bool = False,
            unroll: bool = False):
    """Returns (logits, aux_loss, caches_or_None)."""
    x, aux, caches = forward_hidden(params, inputs, cfg, positions,
                                    collect_cache, unroll)
    return project_logits(params, x, cfg), aux, caches


# ---------------------------------------------------------------------------
# Decode (one token, cache of max_seq)
# ---------------------------------------------------------------------------

def decode_step(params, caches, inputs, cache_len, cfg, unroll=False):
    """inputs: tokens (B,1) or embeddings (B,1,D); cache_len: (B,) int32.

    Returns (logits (B,1,V), new caches).
    """
    B = inputs.shape[0]
    positions = cache_len[:, None]
    x = _embed(params, inputs, cfg, positions)

    def block_body(x, xs):
        bp, bc = xs
        new_c = {}
        for i, (mix, ffn) in enumerate(cfg.block_pattern):
            pp, cc = bp[f"pos{i}"], bc[f"pos{i}"]
            nc = {}
            h = layers.rms_norm(x, pp["norm1"], cfg.norm_eps)
            if mix == "attn":
                mo, kv = layers.attention_decode(pp["mixer"], h, cfg,
                                                 (cc["k"], cc["v"]), cache_len)
                nc["k"], nc["v"] = kv
            elif mix == "mamba":
                mo, st = mamba_lib.mamba_decode(pp["mixer"], h, cfg,
                                                (cc["conv"], cc["h"]))
                nc["conv"], nc["h"] = st
            else:
                mo, st = rwkv_lib.timemix_decode(pp["mixer"], h, cfg,
                                                 (cc["x_tm"], cc["S"]))
                nc["x_tm"], nc["S"] = st
            x = x + mo
            h2 = layers.rms_norm(x, pp["norm2"], cfg.norm_eps)
            if ffn == "dense":
                f = layers.mlp(pp["ffn"], h2)
            elif ffn == "moe":
                f, _ = moe_lib.moe_ffn(pp["ffn"], h2, cfg)
            else:
                xcm_prev = cc["x_cm"]
                f, xcm = rwkv_lib.channelmix(pp["ffn"], h2, cfg, xcm_prev)
                nc["x_cm"] = xcm
            x = x + f
            new_c[f"pos{i}"] = nc
        return x, new_c

    un = (cfg.num_blocks if unroll is True
          else (unroll if isinstance(unroll, int) and unroll else 1))
    x, new_caches = jax.lax.scan(block_body, x, (params["blocks"], caches),
                                 unroll=un)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, new_caches
