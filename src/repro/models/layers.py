"""Transformer building blocks: RMSNorm, RoPE (full/half), GQA attention
(qk-norm, sliding-window, decode-with-cache), SwiGLU MLP.

Pure-JAX (pytree params, no framework).  Weight layouts keep the sharded
dim flattened — W_q is (d_model, H·hd) — so tensor-parallel PartitionSpecs
divide evenly for every assigned architecture (24-head musicgen, 2-KV
chatglm, ...).

Sharding is expressed with logical-axis constraints via `shard()`; the
launcher installs the logical→mesh rules (train/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.train import sharding as shd


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms(key, d, dtype):
    del key
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """(sin, cos) tables for `dim` rotary dims at given positions (...,)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (..., dim/2)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray,
               style: str) -> jnp.ndarray:
    """x: (B, S, H, hd).  style: full | half (GLM 2d-RoPE) | none."""
    if style == "none":
        return x
    hd = x.shape[-1]
    rot = hd if style == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    sin_ = sin[:, :, None, :rot // 2].astype(x.dtype)
    cos_ = cos[:, :, None, :rot // 2].astype(x.dtype)
    o1 = x1 * cos_ - x2 * sin_
    o2 = x2 * cos_ + x1 * sin_
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


def sinusoidal_emb(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), dt) * s,
        "wk": jax.random.normal(k2, (d, kv * hd), dt) * s,
        "wv": jax.random.normal(k3, (d, kv * hd), dt) * s,
        "wo": jax.random.normal(k4, (h * hd, d), dt) * (s / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = shd.shard(jnp.einsum("bsd,dk->bsk", x, p["wq"]), ("batch", "seq", "heads_flat"))
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_style == "rope":
        rot = hd if cfg.rope_style == "full" else hd // 2
        sin, cos = rope_angles(positions, rot, cfg.rope_theta)
        q = apply_rope(q, sin, cos, cfg.rope_style)
        k = apply_rope(k, sin, cos, cfg.rope_style)
    return q, k, v


def _pick_q_block(S: int) -> int:
    """Static query-block size: ≤16 blocks, ≥512 wide (1 block if S small)."""
    if S <= 1024:
        return S
    qb = max(512, -(-S // 16))
    while S % qb:
        qb += 1
    return qb


def attention(p, x, cfg, positions, q_block: Optional[int] = None):
    """Blocked causal attention (train / prefill) — flash-style.

    Queries are processed in static blocks; block i only reads keys
    [lo_i, (i+1)·qb) where lo_i honors the sliding window, so (a) the
    (S, S) score matrix is never materialized (peak is (qb, ≤S) per block)
    and (b) the flop count is the exact causal half, not a masked full
    square.  Static python-loop blocks keep cost_analysis honest (no scan
    body undercounting) and let XLA pipeline HBM reads per block.

    Returns (out (B,S,D), cache (k, v)).
    """
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    g = h // kv
    q, k, v = _qkv(p, x, cfg, positions)
    q = shd.shard(q, ("batch", "seq", "heads", None))
    k = shd.shard(k, ("batch", "seq", "kv_heads", None))
    v = shd.shard(v, ("batch", "seq", "kv_heads", None))

    qb = q_block or _pick_q_block(S)
    win = cfg.sliding_window
    outs = []
    for i in range(S // qb):
        q0, q1 = i * qb, (i + 1) * qb
        lo = 0 if not win else max(0, q0 - win)
        kc, vc = k[:, lo:q1], v[:, lo:q1]
        qg = q[:, q0:q1].reshape(B, qb, kv, g, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc).astype(jnp.float32)
        scores = scores / math.sqrt(hd)
        qpos = positions[:, q0:q1, None]                 # (B,qb,1)
        kpos = positions[:, None, lo:q1]                 # (B,1,kc)
        mask = kpos <= qpos
        if win:
            mask = mask & (kpos > qpos - win)
        scores = jnp.where(mask[:, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        outs.append(jnp.einsum("bkgqs,bskh->bqkgh", probs, vc)
                    .reshape(B, qb, h * hd))
    out = jnp.concatenate(outs, axis=1)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    return shd.shard(out, ("batch", "seq", None)), (k, v)


def attention_decode(p, x, cfg, cache, cache_len):
    """One-token decode against a KV cache.

    x: (B, 1, D); cache: (k, v) each (B, S_cache, KV, hd); cache_len: (B,)
    current lengths (the new token is written at position cache_len).
    Returns (out (B,1,D), new cache).
    """
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    g = h // kv
    ck, cv = cache
    S = ck.shape[1]
    pos = cache_len[:, None]                                   # (B,1)
    q, knew, vnew = _qkv(p, x, cfg, pos)

    idx = cache_len[:, None, None, None]                       # scatter position
    span = jnp.arange(S)[None, :, None, None]
    ck = jnp.where(span == idx, knew.astype(ck.dtype), ck)
    cv = jnp.where(span == idx, vnew.astype(cv.dtype), cv)
    ck = shd.shard(ck, ("batch", "cache_seq", "kv_heads", None))
    cv = shd.shard(cv, ("batch", "cache_seq", "kv_heads", None))

    qg = q.reshape(B, 1, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    kpos = jnp.arange(S)[None, :]
    valid = kpos <= cache_len[:, None]
    if cfg.sliding_window:
        valid = valid & (kpos > (cache_len[:, None] - cfg.sliding_window))
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv).reshape(B, 1, h * hd)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    return out, (ck, cv)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "w1": jax.random.normal(k1, (d, f), dt) * s,
        "w3": jax.random.normal(k2, (d, f), dt) * s,
        "w2": jax.random.normal(k3, (f, d), dt) * (1.0 / math.sqrt(f)),
    }


def mlp(p, x):
    hgate = jnp.einsum("bsd,df->bsf", x, p["w1"])
    hup = jnp.einsum("bsd,df->bsf", x, p["w3"])
    hgate = shd.shard(hgate, ("batch", "seq", "ff"))
    h = jax.nn.silu(hgate) * hup
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])
