"""Selective SSM (Mamba-1 style) block for the Jamba hybrid.  [Jamba:
arXiv:2403.19887; Mamba: arXiv:2312.00752]

    h_t = exp(Δ_t A) h_{t-1} + (Δ_t B_t) x_t        (ZOH discretization)
    y_t = C_t · h_t + D x_t,   out = y ⊙ silu(z)

Δ_t, B_t, C_t are input-dependent (the "selective" part).  Full-sequence
training uses a lax.scan over time carrying h (B, d_inner, d_state) — the
exponents Δ·A are ≤ 0, so it is unconditionally stable.  Decode carries
(conv window, h) per layer: O(1) per token, making the hybrid
long_500k-eligible.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.train import sharding as shd


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def d_inner(cfg) -> int:
    return cfg.mamba_expand * cfg.d_model


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg):
    D, DI, NS, R, KC = (cfg.d_model, d_inner(cfg), cfg.mamba_d_state,
                        dt_rank(cfg), cfg.mamba_conv)
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(D)
    a = jnp.tile(jnp.arange(1, NS + 1, dtype=jnp.float32)[None], (DI, 1))
    return {
        "in_proj": jax.random.normal(ks[0], (D, 2 * DI), dt) * s,
        "conv_w": jax.random.normal(ks[1], (KC, DI), dt) / math.sqrt(KC),
        "conv_b": jnp.zeros((DI,), dt),
        "x_proj": jax.random.normal(ks[2], (DI, R + 2 * NS), dt) / math.sqrt(DI),
        "dt_proj": jax.random.normal(ks[3], (R, DI), jnp.float32) / math.sqrt(R),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform dt init
            jnp.exp(jax.random.uniform(ks[4], (DI,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "a_log": jnp.log(a),
        "dcoef": jnp.ones((DI,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (DI, D), dt)
                    * (1.0 / math.sqrt(DI)) / math.sqrt(2 * cfg.num_layers),
    }


def _conv_causal(x, w, b, conv_state=None):
    """Depthwise causal conv over seq.  x: (B,S,DI); w: (K,DI)."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out + b[None, None], xp[:, -(K - 1):]


def _ssm_scan(u, delta, A, B, C, Dc, h0, chunk: int = 256):
    """u/delta: (B,S,DI); A: (DI,NS); B/C: (B,S,NS); h0: (B,DI,NS).

    Chunk-checkpointed: a plain backprop-through-scan would save the
    (B,DI,NS) carry at EVERY timestep (S×B×DI×NS residuals — tens of GB per
    device for jamba train_4k).  The outer scan saves one carry per chunk;
    the inner chunk is rematerialized during backward.  The discretized
    dA = exp(Δ·A) / dBu are computed IN-step from the small (Δ, B, u)
    slices rather than materialized as (B,S,DI,NS) inputs.
    """
    Bb, S, DI = u.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        z3 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        u, delta, B, C = z3(u), z3(delta), z3(B), z3(C)
    T = u.shape[1]
    nc = T // chunk

    def to_chunks(a):                                # (B,T,F) -> (nc,chunk,B,F)
        return a.reshape(Bb, nc, chunk, -1).transpose(1, 2, 0, 3)

    xs = tuple(map(to_chunks, (u, delta, B, C)))

    @jax.checkpoint
    def chunk_body(h, xs_c):
        def step(h, xs_t):
            u_t, d_t, b_t, c_t = xs_t                # (B,DI),(B,DI),(B,NS),(B,NS)
            dA = jnp.exp(d_t[..., None] * A[None])   # (B,DI,NS)
            h = dA * h + (d_t * u_t)[..., None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y
        return jax.lax.scan(step, h, xs_c)

    hT, ys = jax.lax.scan(chunk_body, h0, xs)        # ys: (nc,chunk,B,DI)
    y = ys.transpose(2, 0, 1, 3).reshape(Bb, T, DI)[:, :S]
    return y + u[:, :S] * Dc[None, None], hT


def mamba(p, x, cfg, state=None):
    """Full-sequence forward.  Returns (out, (conv_state, h_state))."""
    B, S, D = x.shape
    DI, NS, R, K = d_inner(cfg), cfg.mamba_d_state, dt_rank(cfg), cfg.mamba_conv
    conv_state = None if state is None else state[0]
    h0 = jnp.zeros((B, DI, NS), jnp.float32) if state is None else state[1]

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shd.shard(xz, ("batch", "seq", "d_inner"))
    u, z = jnp.split(xz, 2, axis=-1)
    u, new_conv = _conv_causal(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u).astype(jnp.float32)

    proj = jnp.einsum("bse,ef->bsf", u.astype(p["x_proj"].dtype), p["x_proj"])
    dt_in, Bm, Cm = jnp.split(proj.astype(jnp.float32), [R, R + NS], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["a_log"])

    y, hT = _ssm_scan(u, delta, A, Bm, Cm, p["dcoef"], h0)
    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(p["out_proj"].dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["out_proj"])
    return shd.shard(out.astype(x.dtype), ("batch", "seq", None)), \
        (new_conv, hT)


def mamba_decode(p, x1, cfg, state):
    """One-token step.  state = (conv window (B,K-1,DI), h (B,DI,NS))."""
    out, new_state = mamba(p, x1, cfg, state)
    return out, new_state
