"""Mixture-of-Experts FFN: top-k router + shard-local sort-based dispatch +
all_to_all expert parallelism.

Routing is computed PER DATA SHARD (the token dim is reshaped to
(W, T/W, ...) with W = the mesh's batch-sharding factor, and all routing
ops are vmapped over that leading sharded dim, so sorts/gathers/scatters
never cross shards — GSPMD partitions them trivially).  The dispatched
buffer (W, E, cap_w, D) is then resharded from W-over-data to
E-over-data — exactly the expert-parallel all_to_all — experts compute
with their FFN dim tensor-parallel over "model", and the combine reverses
the path.

A GLOBAL-index scatter over all W shards (the naive formulation) makes
GSPMD partition arbitrary-index scatter/gather — it replicates the token
buffer per device and its backward is pathologically slow to partition
(observed: jamba train_4k compile hang >20 min, olmoe 332 GiB/device).
The shard-local form compiles in seconds.  Tokens over a shard-local
expert capacity are dropped (standard capacity-factor semantics).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.train import sharding as shd


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s,
        "we1": jax.random.normal(k2, (e, d, f), dt) * s,
        "we3": jax.random.normal(k3, (e, d, f), dt) * s,
        "we2": jax.random.normal(k4, (e, f, d), dt) * (1.0 / math.sqrt(f)),
    }


def _batch_shards(B: int) -> int:
    """How many ways the token dim is sharded on the active mesh."""
    mesh = shd._mesh()
    rules = shd._rules()
    if mesh is None or rules is None:
        return 1
    ax = rules.get("batch")
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    w = 1
    for a in axes:
        w *= mesh.shape[a]
    return w if B % w == 0 else 1


def _route_local(xw, p, cfg, cap):
    """Shard-local dispatch.  xw: (Tw, D) tokens of ONE shard slice.

    Returns (xe (E, cap, D), meta for the combine).
    """
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    Tw, D = xw.shape
    logits = jnp.einsum("td,de->te", xw.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[eidx.reshape(-1)].add(1.0) / (Tw * K)
    aux = E * jnp.sum(me * ce)

    flat_e = eidx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(Tw), K)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(Tw * K) - offsets[se]
    keep = rank < cap
    slot_e = jnp.where(keep, se, E - 1)
    slot_c = jnp.where(keep, rank, cap - 1)
    xe = jnp.zeros((E, cap, D), xw.dtype).at[slot_e, slot_c].add(
        jnp.where(keep[:, None], xw[st], 0).astype(xw.dtype))
    return xe, (st, sg, slot_e, slot_c, keep), aux


def _combine_local(ye, meta, Tw, dtype):
    st, sg, slot_e, slot_c, keep = meta
    back = ye[slot_e, slot_c]
    contrib = jnp.where(keep[:, None], back * sg[:, None].astype(dtype), 0)
    D = ye.shape[-1]
    return jnp.zeros((Tw, D), dtype).at[st].add(contrib)


def moe_ffn(p, x, cfg):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    W = _batch_shards(B)
    Tw = T // W
    cap = max(4, int(math.ceil(Tw * K / E * cfg.capacity_factor)))

    xw = x.reshape(W, Tw, D)                                # W over "batch" axes
    xw = shd.shard(xw, ("batch", None, None))
    xe, meta, aux = jax.vmap(
        lambda t: _route_local(t, p, cfg, cap))(xw)          # (W, E, cap, D)
    aux = aux.mean()

    # expert-parallel resharding: W-over-batch-axes -> E-over-expert-axis
    # (all_to_all).  In the pure-EP layout ("experts" mapped to the same
    # axis as "ff") experts own their whole FFN, so the inner dim must NOT
    # also be constrained to that axis.
    rules = shd._rules()
    ep_pure = rules is not None and rules.get("experts") is not None \
        and rules.get("experts") == rules.get("ff")
    # pure-EP: tokens STAY sharded over the batch axes while experts carry
    # the model axis — 2-D (W, E) sharding, 256-way parallel compute, and
    # neither expert matmul contracts a sharded dim (no per-layer AR).
    wdim = "batch" if ep_pure else None
    xe = shd.shard(xe, (wdim, "experts", None, None))
    h1 = jnp.einsum("wecd,edf->wecf", xe, p["we1"])
    h3 = jnp.einsum("wecd,edf->wecf", xe, p["we3"])
    h = jax.nn.silu(h1) * h3
    h = shd.shard(h, (wdim, "experts", None, None if ep_pure else "ff"))
    ye = jnp.einsum("wecf,efd->wecd", h, p["we2"])
    ye = shd.shard(ye, (wdim, "experts", None, None))

    # back to token-major sharding for the combine (reverse all_to_all)
    ye = shd.shard(ye, ("batch", None, None, None))
    out = jax.vmap(lambda y, m: _combine_local(y, m, Tw, x.dtype))(ye, meta)
    out = shd.shard(out.reshape(B, S, D), ("batch", "seq", None))
    return out, aux
