"""llama3-8b-sw8k [dense variant] — llama3-8b with an 8192-token sliding
window, making the long_500k decode shape runnable for a dense arch
(DESIGN.md §5: "dense archs only if you implement a sliding-window ...
variant").  Beyond-assignment extra config; the canonical llama3-8b entry
is unchanged.
"""
import dataclasses

from repro.configs.base import register
from repro.configs.llama3_8b import CONFIG as _BASE

CONFIG = register(dataclasses.replace(
    _BASE, name="llama3-8b-sw8k", sliding_window=8192))
