"""olmoe-1b-7b [moe] — 64 experts, top-8.

[arXiv:2409.02060] 16L, d_model 2048, 16 heads (kv=16 -> MHA),
expert d_ff 1024, vocab 50304, 64 experts top-8 (1B active / 7B total).
OLMoE uses qk-norm.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                  # per-expert FFN width
    vocab_size=50304,
    num_experts=64,
    num_experts_per_tok=8,
    qk_norm=True,
))
