"""llava-next-mistral-7b [vlm] — anyres tiling over a Mistral-7B backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] Backbone: 32L, d_model 4096,
32 heads / 8 KV, d_ff 14336, vocab 32000, sliding-window 4096 (Mistral).
The SigLIP/CLIP vision tower + anyres tile projector are STUBBED per the
assignment carve-out: input_specs() provides precomputed patch+text
embeddings (B, S, d_model); this is the language decoder that consumes
them.  Sliding-window attention makes it long_500k-eligible.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,        # Mistral SWA
    input_mode="embeddings",
    rope_theta=1e6,
))
