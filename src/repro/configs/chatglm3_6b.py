"""chatglm3-6b [dense] — RoPE 2d (rotary on half the head dims), GQA kv=2.

[arXiv:2406.12793] ChatGLM family: 28L, d_model 4096, 32 heads with
2 KV (multi-query-ish GQA), d_ff 13696, vocab 65024.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",          # GLM 2d-RoPE: rotary applied to half of head_dim
    rope_theta=1e4,
))
