"""granite-3-2b [dense] — GQA kv=8.

[hf:ibm-granite/granite-3.0-2b-base] 40L, d_model 2048, 32 heads / 8 KV,
d_ff 8192, vocab 49155.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    rope_theta=1e4,
))
