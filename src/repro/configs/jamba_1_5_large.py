"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.

[arXiv:2403.19887 / Jamba-1.5] 72L, d_model 8192, 64 heads / 8 KV,
d_ff 24576, vocab 65536, MoE 16 experts top-2 on alternate layers
(94B active / 398B total), one attention layer per 8-layer block,
Mamba d_state 16.  Sub-quadratic per token -> runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mixer="hybrid",
    attn_period=8,              # 1 attention : 7 mamba
    num_experts=16,
    num_experts_per_tok=2,
    moe_every=2,                # MoE on alternate layers (Jamba design)
    mamba_d_state=16,
    mamba_expand=2,
))
