"""qwen3-0.6b [dense] — qk_norm, GQA kv=8.

[hf:Qwen/Qwen3-8B family card] 28L, d_model 1024, 16 heads / 8 KV,
explicit head_dim 128, d_ff 3072, vocab 151936, RMSNorm on q/k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,               # Qwen3 decouples head_dim from d_model/heads
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
))
