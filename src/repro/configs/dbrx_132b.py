"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

[hf:databricks/dbrx-base] 40L, d_model 6144, 48 heads / 8 KV,
expert d_ff 10752, vocab 100352, 16 experts top-4 (36B active / 132B total).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,                 # per-expert FFN width
    vocab_size=100352,
    num_experts=16,
    num_experts_per_tok=4,
    rope_theta=5e5,
))
