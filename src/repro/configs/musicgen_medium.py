"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284] 48L, d_model 1536, 24 heads (kv=24 -> MHA), d_ff 6144,
vocab 2048 (EnCodec codebook).  The EnCodec conv codec frontend is STUBBED
per the assignment carve-out: input_specs() provides precomputed frame
embeddings; this model is the decoder transformer that consumes them.
MusicGen uses sinusoidal positions (no RoPE).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pos_style="sinusoidal",
    rope_style="none",
    input_mode="embeddings",
))
