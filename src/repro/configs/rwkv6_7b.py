"""rwkv6-7b [ssm] — "Finch", attention-free, data-dependent decay.

[arXiv:2404.05892] RWKV-6 World 7B: 32L, d_model 4096 (64 heads × 64),
channel-mix d_ff 14336, vocab 65536.  O(1)/token state -> runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,               # RWKV6 head size 64
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    mixer="rwkv",
    rope_style="none",
    pos_style="none",
))
