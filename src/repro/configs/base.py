"""Architecture config schema + registry + the four assigned input shapes.

Every assigned architecture file in this package instantiates `ArchConfig`
with the exact figures from its source paper/model card (cited in each
file).  `reduced()` yields the 2-layer smoke variant required by the
assignment (d_model ≤ 512, ≤ 4 experts), used by tests/test_arch_smoke.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# The four assigned input shapes (assignment block).
INPUT_SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # attention flavor
    rope_style: str = "full"     # full | half (2d-RoPE: rotary on half dims) | none
    pos_style: str = "rope"      # rope | sinusoidal (musicgen)
    qk_norm: bool = False        # qwen3
    rope_theta: float = 1e4
    sliding_window: int = 0      # 0 = full attention (mistral/llava: 4096)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_every: int = 1           # apply MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25

    # layer mixture
    mixer: str = "attn"          # attn | rwkv | hybrid (jamba)
    attn_period: int = 0         # hybrid: one attn layer per `attn_period` layers
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4

    # io
    input_mode: str = "tokens"   # tokens | embeddings (audio/vlm frontend stub)
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def block_pattern(self) -> tuple[tuple[str, str], ...]:
        """((mixer, ffn), ...) for one scanned block; model = num_blocks × pattern."""
        if self.mixer == "hybrid":
            p = self.attn_period
            pat = []
            for i in range(p):
                mix = "attn" if i == p - 1 else "mamba"
                ffn = "moe" if (self.num_experts and i % self.moe_every == 1) else "dense"
                pat.append((mix, ffn))
            return tuple(pat)
        if self.mixer == "rwkv":
            return (("rwkv", "channelmix"),)
        ffn = "moe" if self.num_experts else "dense"
        return (("attn", ffn),)

    @property
    def num_blocks(self) -> int:
        p = len(self.block_pattern)
        assert self.num_layers % p == 0, (self.name, self.num_layers, p)
        return self.num_layers // p

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic attention available (for long_500k eligibility)."""
        return self.mixer in ("rwkv", "hybrid") or self.sliding_window > 0

    def reduced(self) -> "ArchConfig":
        """2-layer smoke variant: d_model<=512, <=4 experts, small vocab."""
        p = len(self.block_pattern)
        layers = p if p >= 2 else 2
        d = min(self.d_model, 256)
        heads = 4
        kv = min(self.num_kv_heads, heads)
        kv = next(k for k in range(kv, 0, -1) if heads % k == 0)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers, d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=64, d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32")


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import importlib
    for mod in ("chatglm3_6b", "qwen3_0_6b", "granite_3_2b", "rwkv6_7b",
                "jamba_1_5_large", "musicgen_medium", "llama3_8b",
                "llama3_8b_sw", "olmoe_1b_7b", "dbrx_132b",
                "llava_next_mistral_7b"):
        importlib.import_module(f"repro.configs.{mod}")
