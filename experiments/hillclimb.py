"""§Perf hillclimb driver: re-lower a (arch, shape) combo under different
sharding/config overrides and print the roofline-term deltas.

  PYTHONPATH=src python experiments/hillclimb.py dbrx-132b prefill_32k \
      --override embed_fsdp=None --tag no-fsdp-gather
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun  # noqa: E402


def parse_override(s: str):
    k, v = s.split("=", 1)
    if v == "None":
        return k, None
    if "," in v:
        return k, tuple(v.split(","))
    return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--no-accounting", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb.jsonl")
    args = ap.parse_args()

    overrides = dict(parse_override(s) for s in args.override) or None
    rec = dryrun.run_one(args.arch, args.shape, overrides=overrides,
                         accounting=not args.no_accounting)
    rec["tag"] = args.tag
    rec["overrides"] = {k: list(v) if isinstance(v, tuple) else v
                        for k, v in (overrides or {}).items()}
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    if rec["status"] == "ok" and "roofline" in rec:
        r = rec["roofline"]
        print(f"\n[{args.tag}] {args.arch} {args.shape}")
        print(f"  mem/dev   : {rec['memory']['total_bytes_per_device']/2**30:.2f} GiB")
        print(f"  compute   : {r['compute_s']*1e3:.2f} ms")
        print(f"  memory    : {r['memory_s']*1e3:.2f} ms")
        print(f"  collective: {r['collective_s']*1e3:.2f} ms  <- {r['dominant']} dominant")
        print(f"  useful    : {r['useful_flops_ratio']:.3f}")
        print(f"  colls     : {r['collective_counts']}")


if __name__ == "__main__":
    main()
