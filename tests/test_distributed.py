"""Distributed DRF engine tests — run in a subprocess with 8 forced host
devices so the main pytest process keeps its single real device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_supersplits_exact():
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import splits, distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(0)
        n, m, L, C = 512, 8, 3, 2
        num = rng.normal(size=(n, m)).astype(np.float32)
        y = rng.integers(0, C, n).astype(np.int32)
        w = rng.integers(0, 3, n).astype(np.float32)
        leaf = rng.integers(0, L + 1, n).astype(np.int32)
        si = np.argsort(num.T, axis=-1, kind='stable').astype(np.int32)
        sv = np.take_along_axis(num.T, si, -1)
        stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C,
                                 'classification')
        cand = np.ones((m, L + 1), bool); cand[:, 0] = False
        ref_g, ref_t = jax.vmap(
            lambda v, s, c: splits.best_numeric_split_segment(
                v, jnp.asarray(leaf)[s], jnp.asarray(w)[s], stats[s], c, L)
        )(jnp.asarray(sv), jnp.asarray(si), jnp.asarray(cand))
        for maker in (distributed.make_column_sharded_supersplit,
                      distributed.make_2d_sharded_supersplit):
            fn = maker(mesh)
            g, t = fn(jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf),
                      jnp.asarray(w), stats, jnp.asarray(cand), L,
                      'gini', 'classification', 1.0)
            fin = np.isfinite(np.asarray(ref_g))
            assert (np.isfinite(np.asarray(g)) == fin).all()
            np.testing.assert_allclose(np.asarray(g)[fin],
                                       np.asarray(ref_g)[fin], atol=1e-3)
            np.testing.assert_allclose(np.asarray(t)[fin],
                                       np.asarray(ref_t)[fin], atol=1e-4)
        print('SHARDED-EXACT-OK')
    """))


@pytest.mark.slow
def test_distributed_forest_equals_local():
    """Full tree built with the 2-D sharded supersplit == local tree."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(1)
        n = 1024
        num = rng.normal(size=(n, 8)).astype(np.float32)
        y = ((num[:, 0] + num[:, 1] * num[:, 2]) > 0).astype(np.int32)
        ds = from_numpy(num, None, y)
        p = tree_lib.TreeParams(max_depth=4, leaf_pad=8)
        local = RandomForest(p, num_trees=2, seed=11).fit(ds)
        fn = distributed.make_2d_sharded_supersplit(mesh)
        dist = RandomForest(p, num_trees=2, seed=11).fit(ds, supersplit_fn=fn)
        for ta, tb in zip(local.trees, dist.trees):
            assert ta.num_nodes == tb.num_nodes
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_allclose(ta.threshold, tb.threshold, atol=1e-4)
        print('DIST-FOREST-OK')
    """))


@pytest.mark.slow
def test_hist_sharded_supersplit_psum_merge():
    """Histogram (PLANET-style) supersplit on the 2x4 mesh: per-shard
    (bins × stats) tables merged by ONE psum over the data axis must give
    the same forest as the local hist search — the network-complexity
    contrast baseline to the exact all_gather (DESIGN.md §6)."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(1)
        n = 1024
        num = rng.normal(size=(n, 8)).astype(np.float32)
        y = ((num[:, 0] + num[:, 1] * num[:, 2]) > 0).astype(np.int32)
        ds = from_numpy(num, None, y)
        B = 32
        p = tree_lib.TreeParams(max_depth=4, leaf_pad=8, split_mode='hist',
                                num_bins=B)
        local = RandomForest(p, num_trees=2, seed=11).fit(ds)
        fn = distributed.make_hist_sharded_supersplit(mesh)
        dist = RandomForest(p, num_trees=2, seed=11).fit(ds, supersplit_fn=fn)
        for ta, tb in zip(local.trees, dist.trees):
            assert ta.num_nodes == tb.num_nodes
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_array_equal(ta.threshold, tb.threshold)
        print('HIST-PSUM-OK')
    """))


@pytest.mark.slow
def test_sharded_batched_forest_exact_and_hist():
    """The tentpole contract (ISSUE 4): sharded exact AND hist training run
    through the BATCHED build_forest path (tree_batch > 1) on the 2x4 mesh,
    produce trees bit-identical to the local batched builder, and issue D
    (one per depth) — not T·D — level programs for the whole batch."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(1)
        n = 1024
        num = rng.normal(size=(n, 8)).astype(np.float32)
        y = ((num[:, 0] + num[:, 1] * num[:, 2]) > 0).astype(np.int32)
        ds = from_numpy(num, None, y)
        configs = [
            (tree_lib.TreeParams(max_depth=4, leaf_pad=8),
             distributed.make_2d_sharded_supersplit(mesh)),
            (tree_lib.TreeParams(max_depth=4, leaf_pad=8, split_mode='hist',
                                 num_bins=32),
             distributed.make_hist_sharded_supersplit(mesh)),
        ]
        for p, eng in configs:
            local = RandomForest(p, num_trees=4, seed=11, tree_batch=4).fit(ds)
            c0 = tree_lib._BATCH_STEP_CALLS[0]
            s0 = tree_lib._STEP_CALLS[0]
            dist = RandomForest(p, num_trees=4, seed=11,
                                tree_batch=4).fit(ds, engine=eng)
            D = max(t.max_depth_reached for t in dist.trees)
            programs = tree_lib._BATCH_STEP_CALLS[0] - c0
            assert D <= programs <= p.max_depth + 1, (programs, D)
            assert tree_lib._STEP_CALLS[0] == s0      # no per-tree fallback
            for ta, tb in zip(local.trees, dist.trees):
                assert ta.num_nodes == tb.num_nodes
                np.testing.assert_array_equal(ta.feature, tb.feature)
                np.testing.assert_array_equal(ta.threshold, tb.threshold)
                np.testing.assert_array_equal(ta.value, tb.value)
        print('SHARDED-BATCHED-OK')
    """))


@pytest.mark.slow
def test_sharded_hist_subtraction_bit_identical():
    """ISSUE 5 tentpole on the 2x4 mesh: ShardedHistNumeric with histogram
    subtraction (packed build-slot tables psum'd, siblings derived as
    parent − sibling) must equal BOTH its own plain rebuild and the local
    builder node-for-node, batched and per-tree, with prune_closed_frac
    on — pruning renumbers rows, not leaves, so the carried tables
    survive row compaction under the mesh too."""
    print(_run("""
        import dataclasses
        import numpy as np
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(5)
        n = 2048
        num = rng.normal(size=(n, 8)).astype(np.float32)
        y = ((num[:, 0] > 0.8) | (num[:, 1] * num[:, 2] > 1.0)).astype(np.int32)
        ds = from_numpy(num, None, y)
        p = tree_lib.TreeParams(max_depth=6, min_records=20, leaf_pad=8,
                                split_mode='hist', num_bins=32,
                                prune_closed_frac=0.3)
        eng = distributed.make_hist_sharded_supersplit(mesh)
        def fingerprint(rf):
            return [(t.num_nodes, t.feature.tolist(), t.threshold.tolist(),
                     t.value.tolist()) for t in rf.trees]
        local = RandomForest(p, num_trees=4, seed=11, tree_batch=4).fit(ds)
        for tb in (4, 1):
            sub = RandomForest(p, num_trees=4, seed=11,
                               tree_batch=tb).fit(ds, engine=eng)
            plain = RandomForest(
                dataclasses.replace(p, hist_subtract=False), num_trees=4,
                seed=11, tree_batch=tb).fit(ds, engine=eng)
            assert fingerprint(sub) == fingerprint(plain), tb
            assert fingerprint(sub) == fingerprint(local), tb
        print('SHARDED-HIST-SUBTRACT-OK')
    """))


@pytest.mark.slow
def test_sharded_pruning_through_batched_builder():
    """prune_closed_frac under the mesh: the batched driver drops only
    common-closed rows rounded to the row-shard width, so shard_map
    divisibility holds and the forest stays bit-identical."""
    print(_run("""
        import numpy as np
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(0)
        n = 2000
        num = rng.normal(size=(n, 8)).astype(np.float32)
        y = (num[:, 0] > 1.2).astype(np.int32)   # leaves close early
        ds = from_numpy(num, None, y)
        base_p = tree_lib.TreeParams(max_depth=8, min_records=50)
        base = RandomForest(base_p, num_trees=3, seed=3, tree_batch=3).fit(ds)
        import dataclasses
        pp = dataclasses.replace(base_p, prune_closed_frac=0.3)
        dist = RandomForest(pp, num_trees=3, seed=3, tree_batch=3).fit(
            ds, engine=distributed.make_2d_sharded_supersplit(mesh))
        for ta, tb in zip(base.trees, dist.trees):
            assert ta.num_nodes == tb.num_nodes
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_array_equal(ta.threshold, tb.threshold)
        print('SHARDED-PRUNE-OK')
    """))


@pytest.mark.slow
def test_sharded_categorical_engine():
    """The categorical table engine under the mesh (psum of the per-shard
    (leaf, category, stat) tables) equals the local table search."""
    print(_run("""
        import numpy as np
        from repro.core import distributed, tree as tree_lib
        from repro.core.dataset import from_numpy
        from repro.core.forest import RandomForest
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(1)
        n = 1024
        num = rng.normal(size=(n, 8)).astype(np.float32)
        cat = rng.integers(0, 5, size=(n, 4)).astype(np.int32)
        y = ((num[:, 0] > 0) ^ (cat[:, 0] >= 3)).astype(np.int32)
        ds = from_numpy(num, cat, y)
        p = tree_lib.TreeParams(max_depth=4)
        local = RandomForest(p, num_trees=3, seed=7, tree_batch=3).fit(ds)
        dist = RandomForest(p, num_trees=3, seed=7, tree_batch=3).fit(
            ds, engine=distributed.make_2d_sharded_supersplit(mesh),
            cat_engine=distributed.make_categorical_sharded_supersplit(mesh))
        for ta, tb in zip(local.trees, dist.trees):
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_array_equal(ta.threshold, tb.threshold)
            np.testing.assert_array_equal(ta.cat_mask, tb.cat_mask)
        print('SHARDED-CAT-OK')
    """))


@pytest.mark.slow
def test_sharded_bit_broadcast():
    """1-bit condition evaluation via psum over the splitter axis (Alg.2
    step 5/7) matches local evaluation."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        rng = np.random.default_rng(0)
        n, m, L = 256, 8, 3
        num = rng.normal(size=(n, m)).astype(np.float32)
        leaf = rng.integers(0, L + 1, n).astype(np.int32)
        feat = rng.integers(0, m, L + 1).astype(np.int32)
        thr = rng.normal(size=L + 1).astype(np.float32)
        fn = distributed.make_sharded_evaluate(mesh)
        bits = fn(jnp.asarray(num.T), jnp.asarray(leaf), jnp.asarray(feat),
                  jnp.asarray(thr), m)
        expect = num[np.arange(n), feat[leaf]] <= thr[leaf]
        np.testing.assert_array_equal(np.asarray(bits), expect)
        print('BIT-BROADCAST-OK')
    """))
