"""Perf-regression gate (`-m slow`): re-run the smoke benchmarks and fail
on >2× slowdown (or any level-program-count change) vs the committed
``BENCH_smoke_baseline.json`` — see benchmarks/check_regression.py."""
import os

import pytest


@pytest.mark.slow
def test_smoke_benchmarks_within_regression_budget():
    from benchmarks import check_regression

    if not os.path.exists(check_regression.BASELINE_PATH):
        pytest.skip("no committed smoke baseline on this checkout")
    rc = check_regression.main([])
    assert rc == 0, "perf regression vs BENCH_smoke_baseline.json " \
                    "(details on stderr; refresh intentionally with " \
                    "`python -m benchmarks.check_regression --update`)"


def test_check_regression_logic():
    """The comparison rules themselves (pure, fast): ratio gate on walls,
    exact gate on program counters, missing metrics flagged."""
    from benchmarks.check_regression import check

    base = {"forest/batched_s/n4000": 1.0,
            "programs::forest/batched/n4000": 6,
            "hist/exact_s/n4000": 2.0}
    ok = {"forest/batched_s/n4000": 1.9,
          "programs::forest/batched/n4000": 6,
          "hist/exact_s/n4000": 0.5}
    assert check(ok, base, 2.0) == []
    slow = dict(ok, **{"forest/batched_s/n4000": 2.5})
    assert any("x2.50" in f for f in check(slow, base, 2.0))
    drift = dict(ok, **{"programs::forest/batched/n4000": 12})
    assert any("count changed" in f for f in check(drift, base, 2.0))
    missing = {"programs::forest/batched/n4000": 6,
               "hist/exact_s/n4000": 0.5}
    assert any("disappeared" in f for f in check(missing, base, 2.0))
