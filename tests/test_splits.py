"""Supersplit engines: exactness against a brute-force oracle + backend
agreement + hypothesis property tests.

`hypothesis` is an OPTIONAL dev dependency (see DESIGN.md §Testing): when
absent this whole module is skipped at collection instead of erroring the
run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import splits


# ---------------------------------------------------------------------------
# Brute-force oracle (pure numpy, one leaf at a time)
# ---------------------------------------------------------------------------

def brute_best_numeric(vals, y, w, num_classes, impurity="gini",
                       min_records=1.0):
    """Enumerate every midpoint between consecutive distinct in-bag values."""
    order = np.argsort(vals, kind="stable")
    vals, y, w = vals[order], y[order], w[order]
    inbag = w > 0
    if inbag.sum() < 2:
        return -np.inf, 0.0

    def imp(h):
        n = h.sum()
        if n <= 0:
            return 0.0
        if impurity == "gini":
            return n - (h ** 2).sum() / n
        p = h / n
        return -n * (p[p > 0] * np.log(p[p > 0])).sum()

    hist = lambda idx: np.bincount(y[idx], weights=w[idx],
                                   minlength=num_classes).astype(np.float64)
    total = hist(inbag)
    best_g, best_t = -np.inf, 0.0
    iv = vals[inbag]
    for i in range(1, len(iv)):
        if iv[i] <= iv[i - 1]:
            continue
        tau = (iv[i] + iv[i - 1]) / 2
        left_sel = inbag & (vals <= tau)
        right_sel = inbag & (vals > tau)
        hl, hr = hist(left_sel), hist(right_sel)
        if hl.sum() < min_records or hr.sum() < min_records:
            continue
        g = imp(total) - imp(hl) - imp(hr)
        if g > best_g + 1e-9:
            best_g, best_t = g, tau
    return best_g, best_t


def _prep(rng, n, L, C):
    vals = np.sort(rng.normal(size=n)).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C, "classification")
    cand = np.ones(L + 1, bool)
    cand[0] = False
    return vals, leaf, w, y, stats, jnp.asarray(cand)


@pytest.mark.parametrize("backend", ["scan", "segment"])
def test_exact_vs_bruteforce(backend, rng):
    n, L, C = 300, 4, 3
    vals, leaf, w, y, stats, cand = _prep(rng, n, L, C)
    fn = splits.NUMERIC_BACKENDS[backend]
    g, t = fn(jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats,
              cand, L)
    g, t = np.asarray(g), np.asarray(t)
    for h in range(1, L + 1):
        sel = leaf == h
        bg, bt = brute_best_numeric(vals[sel], y[sel], w[sel], C)
        if np.isfinite(bg):
            assert g[h] == pytest.approx(bg, rel=1e-4, abs=1e-4), f"leaf {h}"
            assert t[h] == pytest.approx(bt, rel=1e-4, abs=1e-4), f"leaf {h}"
        else:
            assert not np.isfinite(g[h])


def test_backends_identical(rng):
    for trial in range(5):
        n, L, C = 257, 6, 2
        vals, leaf, w, y, stats, cand = _prep(rng, n, L, C)
        g1, t1 = splits.best_numeric_split_scan(
            jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L)
        g2, t2 = splits.best_numeric_split_segment(
            jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L)
        fin = np.isfinite(np.asarray(g1))
        assert (fin == np.isfinite(np.asarray(g2))).all()
        np.testing.assert_allclose(np.asarray(g1)[fin], np.asarray(g2)[fin],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(t1)[fin], np.asarray(t2)[fin],
                                   rtol=1e-4, atol=1e-4)


def test_categorical_binary_exact(rng):
    """For binary classification the Breiman ordering gives the best subset
    among ALL 2^(V-1) subsets — verify by exhaustive enumeration."""
    n, L, V = 400, 2, 5
    x = rng.integers(0, V, n).astype(np.int32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, 2, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), 2, "classification")
    cand = jnp.asarray([False] + [True] * L)
    g, mask = splits.best_categorical_split(
        jnp.asarray(x), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L, V)
    g = np.asarray(g)

    def imp(h):
        nn = h.sum()
        return nn - (h ** 2).sum() / nn if nn > 0 else 0.0

    for h in range(1, L + 1):
        sel = (leaf == h) & (w > 0)
        best = -np.inf
        total = np.bincount(y[sel], weights=w[sel], minlength=2)
        for subset in range(1, 2 ** V - 1):
            in_s = np.array([(subset >> v) & 1 for v in range(V)], bool)
            lsel = sel & in_s[x]
            hl = np.bincount(y[lsel], weights=w[lsel], minlength=2)
            hr = total - hl
            if hl.sum() < 1 or hr.sum() < 1:
                continue
            best = max(best, imp(total) - imp(hl) - imp(hr))
        if np.isfinite(best):
            assert g[h] == pytest.approx(best, rel=1e-4, abs=1e-4), f"leaf {h}"


@pytest.mark.hypothesis
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(16, 120))
def test_property_backends_agree(seed, C, n):
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 5))
    vals = np.sort(rng.normal(size=n)).astype(np.float32)
    # duplicated values exercise the tie handling
    vals = np.round(vals * 2) / 2
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C, "classification")
    cand = jnp.asarray([False] + [True] * L)
    g1, t1 = splits.best_numeric_split_scan(
        jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L)
    g2, t2 = splits.best_numeric_split_segment(
        jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L)
    fin = np.isfinite(np.asarray(g1))
    assert (fin == np.isfinite(np.asarray(g2))).all()
    np.testing.assert_allclose(np.asarray(g1)[fin], np.asarray(g2)[fin],
                               rtol=1e-3, atol=1e-3)


@pytest.mark.hypothesis
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_gain_nonnegative_and_split_separates(seed):
    """Invariants: reported gains are >= 0; thresholds lie strictly between
    two observed in-bag values of their leaf."""
    rng = np.random.default_rng(seed)
    n, L = 200, 3
    vals = np.sort(rng.normal(size=n)).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 2, n).astype(np.float32)
    y = rng.integers(0, 2, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), 2, "classification")
    cand = jnp.asarray([False] + [True] * L)
    g, t = splits.best_numeric_split_segment(
        jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L)
    g, t = np.asarray(g), np.asarray(t)
    for h in range(1, L + 1):
        if not np.isfinite(g[h]):
            continue
        assert g[h] >= -1e-5
        iv = vals[(leaf == h) & (w > 0)]
        assert iv.min() < t[h] < iv.max()


def test_regression_variance_gain(rng):
    n, L = 300, 2
    vals = np.sort(rng.normal(size=n)).astype(np.float32)
    leaf = rng.integers(1, L + 1, n).astype(np.int32)
    w = np.ones(n, np.float32)
    y = (vals * 3 + rng.normal(size=n) * 0.1).astype(np.float32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), 2, "regression")
    cand = jnp.asarray([False] + [True] * L)
    g, t = splits.best_numeric_split_segment(
        jnp.asarray(vals), jnp.asarray(leaf), jnp.asarray(w), stats, cand, L,
        impurity="variance", task="regression")
    assert np.isfinite(np.asarray(g)[1:]).all()
    assert (np.asarray(g)[1:] > 0).all()
