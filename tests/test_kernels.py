"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (assignment (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import splits
from repro.kernels import cat_hist, ops, ref


def _mk(seed, n, m, L, C, dup=False):
    rng = np.random.default_rng(seed)
    num = rng.normal(size=(n, m)).astype(np.float32)
    if dup:
        num = np.round(num)                   # heavy ties
    y = rng.integers(0, C, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = np.take_along_axis(num.T, si, -1)
    cand = np.ones((m, L + 1), bool)
    cand[:, 0] = False
    return sv, si, leaf, w, y, cand


def _oracle(sv, si, leaf, w, y, cand, L, C, task="classification",
            impurity="gini", min_records=1.0):
    leaf_g, w_g = leaf[si], w[si]
    y_g = y[si].astype(np.float32)

    def tot(lf, ww, yy):
        st = splits.row_stats(jnp.asarray(yy), jnp.asarray(ww), C, task)
        st = jnp.where(((ww > 0) & (lf > 0))[:, None], st, 0.0)
        return jax.ops.segment_sum(st, lf, num_segments=L + 1)

    totals = jax.vmap(tot)(jnp.asarray(leaf_g), jnp.asarray(w_g),
                           jnp.asarray(y_g))
    return ref.split_scan_ref(
        jnp.asarray(sv), jnp.asarray(leaf_g), jnp.asarray(w_g),
        jnp.asarray(y_g), jnp.asarray(cand, np.float32), totals,
        L1=L + 1, s_dim=C if task == "classification" else 3,
        impurity=impurity, task=task, min_records=min_records)


SWEEP = [
    # (n, m, L, C, bn, dup)
    (256, 2, 1, 2, 64, False),
    (500, 3, 5, 3, 128, False),
    (1000, 4, 7, 2, 256, True),
    (777, 2, 3, 4, 128, True),      # n not multiple of bn -> padding path
    (512, 1, 15, 2, 512, False),    # single block
]


@pytest.mark.parametrize("n,m,L,C,bn,dup", SWEEP)
def test_split_scan_kernel_sweep(n, m, L, C, bn, dup):
    sv, si, leaf, w, y, cand = _mk(n + m, n, m, L, C, dup)
    g_k, t_k = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), L, bn=bn)
    g_r, t_r = _oracle(sv, si, leaf, w, y, cand, L, C)
    gk, gr = np.asarray(g_k), np.asarray(g_r)
    fin = np.isfinite(gr)
    assert (np.isfinite(gk) == fin).all()
    np.testing.assert_allclose(gk[fin], gr[fin], atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t_k)[fin], np.asarray(t_r)[fin],
                               atol=1e-4)


def test_split_scan_kernel_regression_task():
    n, m, L = 512, 2, 3
    rng = np.random.default_rng(0)
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = (num[:, 0] * 2 + rng.normal(size=n) * 0.1).astype(np.float32)
    w = np.ones(n, np.float32)
    leaf = rng.integers(1, L + 1, n).astype(np.int32)
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = np.take_along_axis(num.T, si, -1)
    cand = np.ones((m, L + 1), bool); cand[:, 0] = False
    g_k, t_k = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), L, impurity="variance",
        task="regression", bn=128)
    g_r, t_r = _oracle(sv, si, leaf, w, y, cand, L, 2, task="regression",
                       impurity="variance")
    fin = np.isfinite(np.asarray(g_r))
    np.testing.assert_allclose(np.asarray(g_k)[fin], np.asarray(g_r)[fin],
                               rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("entropy", ["gini", "entropy"])
def test_split_scan_kernel_impurities(entropy):
    sv, si, leaf, w, y, cand = _mk(11, 384, 2, 3, 2)
    g_k, _ = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), 3, impurity=entropy, bn=128)
    g_r, _ = _oracle(sv, si, leaf, w, y, cand, 3, 2, impurity=entropy)
    fin = np.isfinite(np.asarray(g_r))
    np.testing.assert_allclose(np.asarray(g_k)[fin], np.asarray(g_r)[fin],
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("V,bv,bn", [(6, 6, 128), (16, 4, 64), (32, 8, 256)])
def test_cat_hist_kernel_sweep(V, bv, bn):
    n, m, L, C = 512, 3, 4, 3
    rng = np.random.default_rng(V)
    x = rng.integers(0, V, size=(m, n)).astype(np.int32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    tbl_k = cat_hist.cat_hist_pallas(
        jnp.asarray(x), jnp.asarray(np.broadcast_to(leaf, (m, n))),
        jnp.asarray(np.broadcast_to(w, (m, n))),
        jnp.asarray(np.broadcast_to(y.astype(np.float32), (m, n))),
        L1=L + 1, V=V, s_dim=C, bv=bv, bn=bn, interpret=True)
    tbl_r = ref.cat_hist_ref(
        jnp.asarray(x), jnp.asarray(np.broadcast_to(leaf, (m, n))),
        jnp.asarray(np.broadcast_to(w, (m, n))),
        jnp.asarray(np.broadcast_to(y.astype(np.float32), (m, n))),
        L1=L + 1, V=V, s_dim=C)
    np.testing.assert_allclose(np.asarray(tbl_k), np.asarray(tbl_r), atol=1e-4)


def test_kernel_backend_in_tree_builder_matches():
    """TreeParams(backend='kernel') builds the same forest as 'scan'."""
    from repro.core import tree as tree_lib
    from repro.core.dataset import from_numpy
    from repro.core.forest import RandomForest
    rng = np.random.default_rng(2)
    n = 600
    num = rng.normal(size=(n, 3)).astype(np.float32)
    yb = (num[:, 0] * num[:, 1] > 0).astype(np.int32)
    ds = from_numpy(num, None, yb)
    a = RandomForest(tree_lib.TreeParams(max_depth=3, backend="kernel"),
                     num_trees=1, seed=3).fit(ds)
    b = RandomForest(tree_lib.TreeParams(max_depth=3, backend="scan"),
                     num_trees=1, seed=3).fit(ds)
    np.testing.assert_array_equal(a.trees[0].feature, b.trees[0].feature)
    np.testing.assert_allclose(a.trees[0].threshold, b.trees[0].threshold,
                               atol=1e-4)
