"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (assignment (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import splits
from repro.kernels import cat_hist, ops, ref


def _mk(seed, n, m, L, C, dup=False):
    rng = np.random.default_rng(seed)
    num = rng.normal(size=(n, m)).astype(np.float32)
    if dup:
        num = np.round(num)                   # heavy ties
    y = rng.integers(0, C, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = np.take_along_axis(num.T, si, -1)
    cand = np.ones((m, L + 1), bool)
    cand[:, 0] = False
    return sv, si, leaf, w, y, cand


def _oracle(sv, si, leaf, w, y, cand, L, C, task="classification",
            impurity="gini", min_records=1.0):
    leaf_g, w_g = leaf[si], w[si]
    y_g = y[si].astype(np.float32)

    def tot(lf, ww, yy):
        st = splits.row_stats(jnp.asarray(yy), jnp.asarray(ww), C, task)
        st = jnp.where(((ww > 0) & (lf > 0))[:, None], st, 0.0)
        return jax.ops.segment_sum(st, lf, num_segments=L + 1)

    totals = jax.vmap(tot)(jnp.asarray(leaf_g), jnp.asarray(w_g),
                           jnp.asarray(y_g))
    return ref.split_scan_ref(
        jnp.asarray(sv), jnp.asarray(leaf_g), jnp.asarray(w_g),
        jnp.asarray(y_g), jnp.asarray(cand, np.float32), totals,
        L1=L + 1, s_dim=C if task == "classification" else 3,
        impurity=impurity, task=task, min_records=min_records)


SWEEP = [
    # (n, m, L, C, bn, dup)
    (256, 2, 1, 2, 64, False),
    (500, 3, 5, 3, 128, False),
    (1000, 4, 7, 2, 256, True),
    (777, 2, 3, 4, 128, True),      # n not multiple of bn -> padding path
    (512, 1, 15, 2, 512, False),    # single block
]


@pytest.mark.parametrize("n,m,L,C,bn,dup", SWEEP)
def test_split_scan_kernel_sweep(n, m, L, C, bn, dup):
    sv, si, leaf, w, y, cand = _mk(n + m, n, m, L, C, dup)
    g_k, t_k = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), L, bn=bn)
    g_r, t_r = _oracle(sv, si, leaf, w, y, cand, L, C)
    gk, gr = np.asarray(g_k), np.asarray(g_r)
    fin = np.isfinite(gr)
    assert (np.isfinite(gk) == fin).all()
    np.testing.assert_allclose(gk[fin], gr[fin], atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t_k)[fin], np.asarray(t_r)[fin],
                               atol=1e-4)


def test_split_scan_kernel_regression_task():
    n, m, L = 512, 2, 3
    rng = np.random.default_rng(0)
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = (num[:, 0] * 2 + rng.normal(size=n) * 0.1).astype(np.float32)
    w = np.ones(n, np.float32)
    leaf = rng.integers(1, L + 1, n).astype(np.int32)
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = np.take_along_axis(num.T, si, -1)
    cand = np.ones((m, L + 1), bool); cand[:, 0] = False
    g_k, t_k = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), L, impurity="variance",
        task="regression", bn=128)
    g_r, t_r = _oracle(sv, si, leaf, w, y, cand, L, 2, task="regression",
                       impurity="variance")
    fin = np.isfinite(np.asarray(g_r))
    np.testing.assert_allclose(np.asarray(g_k)[fin], np.asarray(g_r)[fin],
                               rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("entropy", ["gini", "entropy"])
def test_split_scan_kernel_impurities(entropy):
    sv, si, leaf, w, y, cand = _mk(11, 384, 2, 3, 2)
    g_k, _ = ops.split_scan_supersplit(
        jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), jnp.asarray(cand), 3, impurity=entropy, bn=128)
    g_r, _ = _oracle(sv, si, leaf, w, y, cand, 3, 2, impurity=entropy)
    fin = np.isfinite(np.asarray(g_r))
    np.testing.assert_allclose(np.asarray(g_k)[fin], np.asarray(g_r)[fin],
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("V,bv,bn", [(6, 6, 128), (16, 4, 64), (32, 8, 256)])
def test_cat_hist_kernel_sweep(V, bv, bn):
    n, m, L, C = 512, 3, 4, 3
    rng = np.random.default_rng(V)
    x = rng.integers(0, V, size=(m, n)).astype(np.int32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    tbl_k = cat_hist.cat_hist_pallas(
        jnp.asarray(x), jnp.asarray(np.broadcast_to(leaf, (m, n))),
        jnp.asarray(np.broadcast_to(w, (m, n))),
        jnp.asarray(np.broadcast_to(y.astype(np.float32), (m, n))),
        L1=L + 1, V=V, s_dim=C, bv=bv, bn=bn, interpret=True)
    tbl_r = ref.cat_hist_ref(
        jnp.asarray(x), jnp.asarray(np.broadcast_to(leaf, (m, n))),
        jnp.asarray(np.broadcast_to(w, (m, n))),
        jnp.asarray(np.broadcast_to(y.astype(np.float32), (m, n))),
        L1=L + 1, V=V, s_dim=C)
    np.testing.assert_allclose(np.asarray(tbl_k), np.asarray(tbl_r), atol=1e-4)


# ---------------------------------------------------------------------------
# Interpret-mode compile-cost bound (ROADMAP "kernel-backend compile cost"):
# off-TPU the row-block grid is unrolled at trace time, so the block count
# must stay bounded no matter how large n grows.
# ---------------------------------------------------------------------------

def test_interpret_grid_plan_bounds_block_count():
    for n in (1_000, 100_000, 1_000_000, 10_000_000, 10**9):
        bn, nblocks, gated = ops._interpret_grid_plan(n, 256)
        assert nblocks <= ops._MAX_INTERPRET_ROW_BLOCKS, n
        assert not gated                       # linear kernels never gate
        assert bn * nblocks >= n
        bn_q, nblocks_q, gated_q = ops._interpret_grid_plan(
            n, 256, quadratic=True)
        # quadratic kernels either fit the bounded unroll with a bounded
        # block size, or gate to the jnp fallback — never an unbounded grid
        assert gated_q or (nblocks_q <= ops._MAX_INTERPRET_ROW_BLOCKS
                           and bn_q <= ops._MAX_INTERPRET_BN), n
    # small n: untouched (bit-compatible with the original block schedule)
    assert ops._interpret_grid_plan(1_000, 256) == (256, 4, False)


def test_split_scan_chunked_blocks_match_default(monkeypatch):
    """Forcing the block-growth path (as if n were huge) must reproduce the
    default-schedule splits — same supersplit, bigger blocks."""
    sv, si, leaf, w, y, cand = _mk(5, 640, 2, 3, 2, dup=True)
    args = (jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf),
            jnp.asarray(w), jnp.asarray(y), jnp.asarray(cand), 3)
    g0, t0 = ops.split_scan_supersplit(*args, bn=64, num_classes=2)
    monkeypatch.setattr(ops, "_MAX_INTERPRET_ROW_BLOCKS", 2)
    g1, t1 = ops.split_scan_supersplit(*args, bn=64, num_classes=2)
    fin = np.isfinite(np.asarray(g0))
    assert (np.isfinite(np.asarray(g1)) == fin).all()
    np.testing.assert_allclose(np.asarray(g1)[fin], np.asarray(g0)[fin],
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t1)[fin], np.asarray(t0)[fin],
                               atol=1e-4)


def test_split_scan_gated_fallback_matches_kernel(monkeypatch):
    """The large-n gate (quadratic block would blow VMEM/compile) answers
    with the exact jnp engine — same splits as the kernel would find."""
    sv, si, leaf, w, y, cand = _mk(9, 512, 2, 4, 3)
    args = (jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf),
            jnp.asarray(w), jnp.asarray(y), jnp.asarray(cand), 4)
    g0, t0 = ops.split_scan_supersplit(*args, bn=64, num_classes=3)
    monkeypatch.setattr(ops, "_MAX_INTERPRET_ROW_BLOCKS", 2)
    monkeypatch.setattr(ops, "_MAX_INTERPRET_BN", 128)   # force the gate
    assert ops._interpret_grid_plan(512, 64, quadratic=True)[2]
    g1, t1 = ops.split_scan_supersplit(*args, bn=64, num_classes=3)
    fin = np.isfinite(np.asarray(g0))
    assert (np.isfinite(np.asarray(g1)) == fin).all()
    np.testing.assert_allclose(np.asarray(g1)[fin], np.asarray(g0)[fin],
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t1)[fin], np.asarray(t0)[fin],
                               atol=1e-4)


def test_cat_hist_chunked_blocks_exact(monkeypatch):
    """cat_hist block growth is exact (integer scatter-adds, order-free)."""
    n, m, L, C, V = 700, 2, 3, 2, 9
    rng = np.random.default_rng(1)
    x = rng.integers(0, V, size=(m, n)).astype(np.int32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    args = (jnp.asarray(x), jnp.asarray(leaf), jnp.asarray(w),
            jnp.asarray(y))
    t0 = ops.categorical_tables(*args, V=V, Lp=L, bn=64, num_classes=C)
    monkeypatch.setattr(ops, "_MAX_INTERPRET_ROW_BLOCKS", 3)
    t1 = ops.categorical_tables(*args, V=V, Lp=L, bn=64, num_classes=C)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t0), atol=1e-5)


def test_kernel_backend_in_tree_builder_matches():
    """TreeParams(backend='kernel') builds the same forest as 'scan'."""
    from repro.core import tree as tree_lib
    from repro.core.dataset import from_numpy
    from repro.core.forest import RandomForest
    rng = np.random.default_rng(2)
    n = 600
    num = rng.normal(size=(n, 3)).astype(np.float32)
    yb = (num[:, 0] * num[:, 1] > 0).astype(np.int32)
    ds = from_numpy(num, None, yb)
    a = RandomForest(tree_lib.TreeParams(max_depth=3, backend="kernel"),
                     num_trees=1, seed=3).fit(ds)
    b = RandomForest(tree_lib.TreeParams(max_depth=3, backend="scan"),
                     num_trees=1, seed=3).fit(ds)
    np.testing.assert_array_equal(a.trees[0].feature, b.trees[0].feature)
    np.testing.assert_allclose(a.trees[0].threshold, b.trees[0].threshold,
                               atol=1e-4)
