"""End-to-end behaviour tests for the paper's system (replaces placeholder).

Validates the paper's headline empirical claims at test scale:
  * DRF learns the §4 synthetic families where rote learning fails (Fig. 1)
  * more training data -> better AUC (Fig. 1 / §5)
  * more trees -> better AUC (Fig. 1)
  * depth-by-depth metrics behave like Fig. 3 (leaves grow, densities < 1)
"""
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular, train_test_split


def _auc_on(family, n, trees=3, depth=10, seed=0, uv=6):
    ds = make_tabular(family, n, num_informative=4, num_useless=uv, seed=seed)
    tr, te = train_test_split(ds)
    rf = RandomForest(tree_lib.TreeParams(max_depth=depth, min_records=1),
                      num_trees=trees, seed=seed).fit(tr)
    return rf.auc(te)


def rote_auc(family, n, seed=0, uv=6):
    """Paper's baseline: label correctly iff the exact row was seen."""
    # continuous features: test rows are (a.s.) never in the training set
    return 0.5


def test_beats_rote_learning_with_useless_variables():
    # 2-dim xor + 8 useless vars (paper's 4-dim instances need ~1e8 rows —
    # Fig. 2 runs them at 3e8; at test scale the 2-dim family carries the
    # same claim: rote learning is stuck at 0.5, DRF is not)
    ds = make_tabular("xor", 5000, num_informative=2, num_useless=8, seed=0)
    tr, te = train_test_split(ds)
    rf = RandomForest(tree_lib.TreeParams(max_depth=12, min_records=1),
                      num_trees=5, seed=0).fit(tr)
    auc = rf.auc(te)
    assert auc > 0.75                      # rote learning = 0.5 (paper Fig. 1)
    assert auc > rote_auc("xor", 5000) + 0.2


def test_more_data_improves_auc():
    small = _auc_on("majority", 500)
    big = _auc_on("majority", 6000)
    assert big > small + 0.02, (small, big)


def test_more_trees_improve_auc():
    one = _auc_on("majority", 2500, trees=1)
    ten = _auc_on("majority", 2500, trees=8)
    assert ten > one, (one, ten)


def test_depth_metrics_like_fig3():
    ds = make_tabular("majority", 3000, num_informative=5, num_useless=3,
                      seed=4)
    rf = RandomForest(tree_lib.TreeParams(max_depth=10, min_records=2),
                      num_trees=1, seed=0).fit(ds, collect_stats=True)
    tr = rf.trees[0]
    stats = rf.level_stats[0]
    leaves_per_level = [s.open_leaves for s in stats]
    # leaves grow in the early levels (they may CLOSE later — min_records)
    assert leaves_per_level[:4] == sorted(leaves_per_level[:4])
    assert max(leaves_per_level) >= 8
    assert 0 < tr.node_density() <= 1.0
    assert 0 <= tr.sample_density() <= 1.0


def test_needle_imbalanced_family_runs():
    auc = _auc_on("needle", 4000, trees=5, depth=12)
    assert np.isfinite(auc)               # highly imbalanced — noisy (paper)
