"""GBT on the stacked predictor (ROADMAP item): `predict_raw` must be ONE
jitted device call over the packed rounds — no host-side tree loop, no
per-round retrace — and numerically match the explicit per-tree sum."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forest as forest_lib
from repro.core import gbt as gbt_lib
from repro.core import tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.gbt import GBTModel, GBTParams


@pytest.fixture(scope="module")
def reg_ds():
    rng = np.random.default_rng(1)
    n = 800
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    return from_numpy(num, None, y, task="regression")


def _host_loop_reference(gbt, num, cat):
    f = np.full((num.shape[0],), gbt.base_score)
    for tr in gbt.trees:
        f = f + gbt.params.learning_rate * np.asarray(
            tr.predict_raw(jnp.asarray(num, jnp.float32),
                           jnp.asarray(cat, jnp.int32)))[:, 0]
    return f


def test_predict_raw_single_call_no_tree_loop(reg_ds):
    gbt = GBTModel(GBTParams(num_rounds=10, max_depth=3,
                             learning_rate=0.3)).fit(reg_ds)
    assert gbt.packed is not None and gbt.packed.num_trees == 10
    ref = _host_loop_reference(gbt, np.asarray(reg_ds.num),
                               np.asarray(reg_ds.cat))

    # the per-tree descent path must be gone entirely
    def boom(*a, **k):
        raise AssertionError("per-tree _predict_jit used by predict_raw")
    saved = tree_lib._predict_jit
    tree_lib._predict_jit = boom
    try:
        traces0 = gbt_lib._RAW_TRACES[0]
        ptraces0 = forest_lib._PREDICT_TRACES[0]
        f1 = gbt.predict_raw(reg_ds.num, reg_ds.cat)
        assert gbt_lib._RAW_TRACES[0] - traces0 <= 1       # one trace
        f2 = gbt.predict_raw(reg_ds.num, reg_ds.cat)
        assert gbt_lib._RAW_TRACES[0] - traces0 <= 1       # no retrace
        assert forest_lib._PREDICT_TRACES[0] - ptraces0 <= 1
    finally:
        tree_lib._predict_jit = saved

    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_allclose(f1, ref, atol=1e-4, rtol=1e-5)


def test_zero_rounds_returns_prior(reg_ds):
    """num_rounds=0 fits the prior only — no trees to pack, no crash."""
    g = GBTModel(GBTParams(num_rounds=0, max_depth=3)).fit(reg_ds)
    f = g.predict_raw(reg_ds.num, reg_ds.cat)
    np.testing.assert_allclose(
        f, np.full(reg_ds.n, g.base_score, np.float32), rtol=1e-6)


def test_logistic_predicts_through_packed_path():
    rng = np.random.default_rng(2)
    n = 700
    num = rng.normal(size=(n, 3)).astype(np.float32)
    yb = (num[:, 0] + num[:, 2] > 0).astype(np.int32)
    ds = from_numpy(num, None, yb)
    g = GBTModel(GBTParams(num_rounds=10, max_depth=3, learning_rate=0.3,
                           loss="logistic")).fit(ds)
    ref = _host_loop_reference(g, np.asarray(ds.num), np.asarray(ds.cat))
    np.testing.assert_allclose(g.predict_raw(ds.num, ds.cat), ref,
                               atol=1e-4, rtol=1e-5)
    proba = g.predict_proba(ds.num, ds.cat)
    assert proba.shape == (n, 2)
    np.testing.assert_allclose(proba.sum(-1), 1.0, atol=1e-6)
    acc = float((g.predict(ds.num, ds.cat) == yb).mean())
    assert acc > 0.9
