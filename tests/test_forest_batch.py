"""Multi-tree batched level step (tree.build_forest, DESIGN.md §3).

The contract under test: `RandomForest.fit` with a tree batch issues ONE
jitted level program per depth per batch, never falls back to per-tree
dispatches, and produces trees BIT-IDENTICAL to the per-tree fused builder
and to `build_tree_reference` — for every backend, for both batched
lowerings (vmap / lax.map), and for forests whose trees finish at
different depths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bagging, presort, tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular


def _presorted(ds):
    if ds.m_num:
        si = presort.presort_columns(ds.num)
        return presort.gather_sorted(ds.num, si), si
    return (jnp.zeros((0, ds.n), jnp.float32), jnp.zeros((0, ds.n), jnp.int32))


def _build_kw(ds, seed=5):
    sv, si = _presorted(ds)
    return dict(num=ds.num, cat=ds.cat, labels=ds.labels, sorted_vals=sv,
                sorted_idx=si, arities=ds.arities,
                num_classes=ds.num_classes, seed=seed)


def _assert_identical(ta, tb, ctx=""):
    assert ta.num_nodes == tb.num_nodes, ctx
    for name in ("feature", "children", "threshold", "is_cat", "cat_mask",
                 "value", "n_node", "gain", "depth"):
        np.testing.assert_array_equal(getattr(ta, name), getattr(tb, name),
                                      err_msg=f"{ctx}:{name}")


@pytest.fixture(scope="module")
def mixed_ds():
    rng = np.random.default_rng(3)
    n = 1100
    num = rng.normal(size=(n, 4)).astype(np.float32)
    cat = rng.integers(0, 5, size=(n, 2)).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 0] >= 3)).astype(np.int32)
    return from_numpy(num, cat, y)


@pytest.mark.parametrize("backend", ["segment", "scan", "kernel"])
def test_batched_matches_reference_per_tree(mixed_ds, backend):
    """Bit-exact parity batched vs per-tree fused vs reference, with trees
    that finish at different depths (early close under max_depth)."""
    kw = _build_kw(mixed_ds)
    p = tree_lib.TreeParams(max_depth=4, backend=backend)
    trees, _ = tree_lib.build_forest(params=p, tree_indices=range(4), **kw)
    depths = {t.max_depth_reached for t in trees}
    assert len(depths) > 1, "fixture must exercise uneven finish depths"
    for t in range(4):
        ref, _ = tree_lib.build_tree_reference(params=p, tree_idx=t, **kw)
        fused, _ = tree_lib.build_tree(params=p, tree_idx=t, **kw)
        _assert_identical(trees[t], ref, f"{backend}/tree{t}/batched-vs-ref")
        _assert_identical(fused, ref, f"{backend}/tree{t}/fused-vs-ref")


def test_batched_map_lowering_matches_reference(mixed_ds, monkeypatch):
    """The large-batch lax.map lowering is bit-exact too (forced on)."""
    monkeypatch.setattr(tree_lib, "_BATCH_VMAP_ELEMS", 0)
    tree_lib._fused_level_step_batched.clear_cache()
    try:
        kw = _build_kw(mixed_ds)
        p = tree_lib.TreeParams(max_depth=4)
        trees, _ = tree_lib.build_forest(params=p, tree_indices=range(3), **kw)
        for t in range(3):
            ref, _ = tree_lib.build_tree_reference(params=p, tree_idx=t, **kw)
            _assert_identical(trees[t], ref, f"map/tree{t}")
    finally:
        tree_lib._fused_level_step_batched.clear_cache()


def test_batched_regression_matches_reference():
    rng = np.random.default_rng(1)
    n = 900
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    ds = from_numpy(num, None, y, task="regression")
    kw = _build_kw(ds, seed=2)
    p = tree_lib.TreeParams(max_depth=5, impurity="variance",
                            task="regression", bagging="none")
    trees, _ = tree_lib.build_forest(params=p, tree_indices=range(3), **kw)
    for t in range(3):
        ref, _ = tree_lib.build_tree_reference(params=p, tree_idx=t, **kw)
        _assert_identical(trees[t], ref, f"regression/tree{t}")


def test_batched_pure_categorical_matches_reference():
    rng = np.random.default_rng(0)
    n = 700
    cat = rng.integers(0, 6, size=(n, 3)).astype(np.int32)
    y = ((cat[:, 0] % 2) ^ (cat[:, 1] >= 3)).astype(np.int32)
    ds = from_numpy(None, cat, y)
    kw = _build_kw(ds)
    p = tree_lib.TreeParams(max_depth=4)
    trees, _ = tree_lib.build_forest(params=p, tree_indices=range(3), **kw)
    for t in range(3):
        ref, _ = tree_lib.build_tree_reference(params=p, tree_idx=t, **kw)
        _assert_identical(trees[t], ref, f"categorical/tree{t}")


def test_fit_chunking_and_auto_batch(mixed_ds):
    """tree_batch chunking covers every tree; auto heuristic is identical."""
    p = tree_lib.TreeParams(max_depth=4)
    a = RandomForest(p, num_trees=7, seed=1, tree_batch=3).fit(mixed_ds)
    b = RandomForest(p, num_trees=7, seed=1, tree_batch=1).fit(mixed_ds)
    c = RandomForest(p, num_trees=7, seed=1).fit(mixed_ds)   # auto
    assert len(a.trees) == len(b.trees) == len(c.trees) == 7
    for ta, tb, tc in zip(a.trees, b.trees, c.trees):
        _assert_identical(ta, tb, "chunk3-vs-pertree")
        _assert_identical(tc, tb, "auto-vs-pertree")
    assert a.packed is not None and a.packed.num_trees == 7


def test_fit_level_stats_match_per_tree(mixed_ds):
    p = tree_lib.TreeParams(max_depth=5)
    a = RandomForest(p, num_trees=3, seed=0, tree_batch=3).fit(
        mixed_ds, collect_stats=True)
    b = RandomForest(p, num_trees=3, seed=0, tree_batch=1).fit(
        mixed_ds, collect_stats=True)
    assert a.level_stats == b.level_stats


def test_one_level_program_per_depth_trace_counted(mixed_ds):
    """fit(n_trees=16) issues ONE batched jitted program per depth level —
    dispatch-counted AND trace-counted — with zero per-tree dispatches."""
    p = tree_lib.TreeParams(max_depth=4, backend="segment")
    rf = RandomForest(p, num_trees=16, seed=0, tree_batch=16)
    rf.fit(mixed_ds)                                   # warm the jit caches

    calls0 = tree_lib._BATCH_STEP_CALLS[0]
    steps0 = tree_lib._STEP_CALLS[0]
    traces0 = tree_lib._BATCH_STEP_TRACES[0]
    rf2 = RandomForest(p, num_trees=16, seed=0, tree_batch=16).fit(mixed_ds)
    calls = tree_lib._BATCH_STEP_CALLS[0] - calls0
    D = max(t.max_depth_reached for t in rf2.trees)
    # one dispatch per depth level actually run, for the whole 16-tree batch
    assert D <= calls <= p.max_depth + 1, (calls, D)
    # no per-tree fused dispatches, no retraces on the warm cache
    assert tree_lib._STEP_CALLS[0] == steps0
    assert tree_lib._BATCH_STEP_TRACES[0] == traces0
    for ta, tb in zip(rf.trees, rf2.trees):
        _assert_identical(ta, tb, "warm-vs-cold")


def test_bag_counts_forest_bitexact_per_tree():
    """The stacked bootstrap draw equals the per-tree draw, per tree."""
    for mode in ("poisson", "multinomial", "none"):
        wb = np.asarray(bagging.bag_counts_forest(
            3, jnp.arange(5), 1000, mode))
        for t in range(5):
            np.testing.assert_array_equal(
                wb[t], np.asarray(bagging.bag_counts(3, t, 1000, mode)),
                err_msg=f"{mode}/tree{t}")


def test_candidate_features_padding_independent():
    """Row h of the candidate mask must not depend on the padded leaf count
    — the property that makes batch-max padding bit-safe (DESIGN.md §3)."""
    key = jax.random.PRNGKey(42)
    small = np.asarray(bagging.candidate_features(key, 2, 4, 10, 3))
    large = np.asarray(bagging.candidate_features(key, 2, 32, 10, 3))
    np.testing.assert_array_equal(small, large[:4])
    # usb draws one shared row; also padding-independent
    su = np.asarray(bagging.candidate_features(key, 2, 4, 10, 3, usb=True))
    lu = np.asarray(bagging.candidate_features(key, 2, 32, 10, 3, usb=True))
    np.testing.assert_array_equal(su, lu[:4])


def test_device_resident_pruning_still_exact():
    """prune_closed_frac (now a device-side closed-prefix slice) must not
    change the model, batched or not."""
    rng = np.random.default_rng(0)
    n = 2000
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (num[:, 0] > 1.2).astype(np.int32)   # skewed: leaves close early
    ds = from_numpy(num, None, y)
    base = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=50),
                        num_trees=2, seed=3).fit(ds)
    for backend in ("segment", "scan"):
        pruned = RandomForest(
            tree_lib.TreeParams(max_depth=8, min_records=50, backend=backend,
                                prune_closed_frac=0.3),
            num_trees=2, seed=3).fit(ds)
        for ta, tb in zip(base.trees, pruned.trees):
            assert ta.num_nodes == tb.num_nodes
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_allclose(ta.threshold, tb.threshold, atol=1e-4)


def test_batched_pruning_stays_batched_and_exact():
    """Sprint pruning no longer downgrades to the per-tree builder: the
    batched driver drops rows closed in EVERY tree of the batch (a
    result-invariant subset) and keeps issuing one level program per depth
    for the whole batch — bit-identical to the unpruned forest."""
    rng = np.random.default_rng(0)
    n = 2000
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (num[:, 0] > 1.2).astype(np.int32)   # skewed: leaves close early
    ds = from_numpy(num, None, y)
    base = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=50),
                        num_trees=3, seed=3, tree_batch=3).fit(ds)
    for backend in ("segment", "scan"):
        calls0 = tree_lib._BATCH_STEP_CALLS[0]
        steps0 = tree_lib._STEP_CALLS[0]
        pruned = RandomForest(
            tree_lib.TreeParams(max_depth=8, min_records=50, backend=backend,
                                prune_closed_frac=0.3),
            num_trees=3, seed=3, tree_batch=3).fit(ds)
        assert tree_lib._BATCH_STEP_CALLS[0] > calls0, backend
        assert tree_lib._STEP_CALLS[0] == steps0, backend
        for ta, tb in zip(base.trees, pruned.trees):
            _assert_identical(ta, tb, f"batched-pruned/{backend}")


def test_legacy_supersplit_fn_warns_and_uses_per_tree_builder(mixed_ds):
    """A bare supersplit_fn closure (the pre-SplitEngine API) cannot ride
    the batched builder: fit must say so (UserWarning) and fall back to
    the per-tree path — producing the identical forest."""
    import jax

    from repro.core import splits

    def legacy_fn(sorted_vals, sorted_idx, leaf_of, w, stats, cand, Lp,
                  impurity, task, min_records):
        def per_col(v, s, c):
            return splits.best_numeric_split_segment(
                v, leaf_of[s], w[s], stats[s], c, Lp, impurity, task,
                min_records)
        return jax.vmap(per_col)(sorted_vals, sorted_idx, cand)

    p = tree_lib.TreeParams(max_depth=3)
    plain = RandomForest(p, num_trees=2, seed=4).fit(mixed_ds)
    calls0 = tree_lib._BATCH_STEP_CALLS[0]
    steps0 = tree_lib._STEP_CALLS[0]
    with pytest.warns(UserWarning, match="per-tree builder"):
        legacy = RandomForest(p, num_trees=2, seed=4).fit(
            mixed_ds, supersplit_fn=legacy_fn)
    assert tree_lib._BATCH_STEP_CALLS[0] == calls0   # no batched programs
    assert tree_lib._STEP_CALLS[0] > steps0          # per-tree dispatches
    for ta, tb in zip(plain.trees, legacy.trees):
        _assert_identical(ta, tb, "legacy-vs-plain")


def test_forest_smoke_bench_runs(tmp_path, monkeypatch):
    """The forest batching benchmark's smoke mode runs in seconds and emits
    a well-formed BENCH_forest_batch.json."""
    out = tmp_path / "BENCH_forest_batch.json"
    monkeypatch.setenv("BENCH_FOREST_BATCH_JSON", str(out))
    import importlib
    from benchmarks import forest_batch_bench
    importlib.reload(forest_batch_bench)
    report = forest_batch_bench.run(smoke=True)
    assert out.exists()
    assert report["smoke"] is True
    for point in report["points"]:
        assert point["per_tree_s"] > 0 and point["batched_s"] > 0
        assert np.isfinite(point["speedup"])
        assert point["level_programs_batched"] < point["level_programs_per_tree"]
