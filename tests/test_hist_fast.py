"""Histogram fast path: bin cache, fused table build, subtraction.

Contracts under test (ISSUE 5):
  * the bin cache is BIT-PACKED (uint8 up to 256 buckets, uint16 past)
    and num_bins=256 does not overflow/wrap the uint8 ids;
  * `splits.feature_count_tables` (one flat scatter for all columns) and
    the Pallas `feat_hist` kernel build identical tables, equal to the
    old per-column `categorical_count_table` path;
  * subtraction (child = parent − sibling) is BIT-IDENTICAL to a plain
    per-level table rebuild — node for node, batched and per-tree, with
    `prune_closed_frac` on (pruning renumbers rows, not leaves, so the
    carried tables survive);
  * the fast path keeps one batched level program per depth (dispatch-
    and trace-counted), and regression (GBT) forces the plain rebuild;
  * pre-quantized bucket state that disagrees with TreeParams raises at
    fit time instead of being silently ignored.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import presort, splits, tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular


def _assert_identical(ta, tb, ctx=""):
    assert ta.num_nodes == tb.num_nodes, ctx
    for name in ("feature", "children", "threshold", "is_cat", "cat_mask",
                 "value", "n_node", "gain", "depth"):
        np.testing.assert_array_equal(getattr(ta, name), getattr(tb, name),
                                      err_msg=f"{ctx}:{name}")


@pytest.fixture(scope="module")
def skewed_ds():
    rng = np.random.default_rng(0)
    n = 2048
    num = rng.normal(size=(n, 5)).astype(np.float32)
    y = ((num[:, 0] > 1.0) | (num[:, 1] * num[:, 2] > 1.5)).astype(np.int32)
    return from_numpy(num, None, y)


# ---------------------------------------------------------------------------
# Bit-packed bin cache
# ---------------------------------------------------------------------------

def test_bin_cache_dtype_packing():
    assert presort.bin_dtype(16) == jnp.uint8
    assert presort.bin_dtype(255) == jnp.uint8
    assert presort.bin_dtype(256) == jnp.uint8
    assert presort.bin_dtype(257) == jnp.uint16
    assert presort.bin_dtype(4096) == jnp.uint16


@pytest.mark.parametrize("B", [255, 256, 300])
def test_bin_cache_no_overflow_at_high_bin_ids(B):
    """num_bins=256 is the uint8 edge: ids up to 255 must survive the
    packed dtype un-wrapped (and 300 bins must pick uint16)."""
    rng = np.random.default_rng(1)
    n = 4096
    num = rng.permutation(n).astype(np.float32)[:, None]  # n distinct values
    si = presort.presort_columns(jnp.asarray(num))
    sv = presort.gather_sorted(jnp.asarray(num), si)
    bins, edges = presort.quantize(jnp.asarray(num), sv, B)
    assert bins.dtype == presort.bin_dtype(B)
    b = np.asarray(bins)[0]
    assert b.min() == 0 and int(b.max()) == B - 1       # top bucket reached
    # packed ids agree with an unpacked int32 searchsorted reference
    ref = np.searchsorted(np.asarray(edges)[0, :-1], num[:, 0], side="left")
    np.testing.assert_array_equal(b.astype(np.int64), ref)
    # the partition rule survives the packing at every cut incl. 254/255
    for cut in (0, B // 2, B - 2):
        np.testing.assert_array_equal(
            b <= cut, num[:, 0] <= np.asarray(edges)[0, cut])


def test_hist_forest_at_256_bins_trains_and_uses_edges(skewed_ds):
    """End-to-end uint8 guard: a 256-bin fit must produce edge thresholds
    and match its own hist_subtract=False rebuild bit-for-bit."""
    p = tree_lib.TreeParams(max_depth=4, split_mode="hist", num_bins=256)
    rf = RandomForest(p, num_trees=2, seed=2).fit(skewed_ds)
    rf2 = RandomForest(dataclasses.replace(p, hist_subtract=False),
                       num_trees=2, seed=2).fit(skewed_ds)
    edges = np.asarray(skewed_ds.quantize(256)[1])
    checked = 0
    for ta, tb in zip(rf.trees, rf2.trees):
        _assert_identical(ta, tb, "256-bins")
        for i in range(ta.num_nodes):
            j = ta.feature[i]
            if j >= 0:
                assert ta.threshold[i] in edges[j]
                checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# Fused multi-feature table build
# ---------------------------------------------------------------------------

def test_feature_tables_match_per_column_and_kernel():
    rng = np.random.default_rng(2)
    n, m, L, B, C = 900, 6, 5, 33, 3
    bins = rng.integers(0, B, size=(m, n)).astype(np.uint8)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C,
                             "classification")
    fused = splits.feature_count_tables(
        jnp.asarray(bins), jnp.asarray(leaf), jnp.asarray(w), stats, L, B)
    per_col = jnp.stack([
        splits.categorical_count_table(
            jnp.asarray(bins[j].astype(np.int32)), jnp.asarray(leaf),
            jnp.asarray(w), stats, L, B) for j in range(m)])
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(per_col))

    from repro.kernels import ops as kops
    kern = kops.feature_tables(
        jnp.asarray(bins), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), B=B, W=L + 1, num_classes=C)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(kern))


def test_feature_tables_discard_slot_rows_do_not_leak():
    """Rows mapped to slot 0 (the subtraction path's derive rows) must
    leave every real slot untouched and slot 0 all-zero."""
    rng = np.random.default_rng(3)
    n, m, L, B = 400, 3, 4, 9
    bins = rng.integers(0, B, size=(m, n)).astype(np.uint8)
    slots = rng.integers(0, L + 1, n).astype(np.int32)
    w = np.ones(n, np.float32)
    stats = jnp.ones((n, 2), jnp.float32)
    full = splits.feature_count_tables(
        jnp.asarray(bins), jnp.asarray(slots), jnp.asarray(w), stats, L, B)
    assert np.asarray(full)[:, 0].sum() == 0                 # slot 0 empty
    # zeroing a slot's rows changes only that slot
    slots2 = np.where(slots == 2, 0, slots)
    part = splits.feature_count_tables(
        jnp.asarray(bins), jnp.asarray(slots2), jnp.asarray(w), stats, L, B)
    np.testing.assert_array_equal(np.asarray(part)[:, 1],
                                  np.asarray(full)[:, 1])
    np.testing.assert_array_equal(np.asarray(part)[:, 3:],
                                  np.asarray(full)[:, 3:])
    assert np.asarray(part)[:, 2].sum() == 0


# ---------------------------------------------------------------------------
# Subtraction vs plain rebuild (the tentpole bit-parity contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["segment", "kernel"])
@pytest.mark.parametrize("tree_batch", [1, 4])
def test_subtraction_bit_identical_to_plain(skewed_ds, backend, tree_batch):
    p = tree_lib.TreeParams(max_depth=6, min_records=20, backend=backend,
                            split_mode="hist", num_bins=32)
    sub = RandomForest(p, num_trees=4, seed=9,
                       tree_batch=tree_batch).fit(skewed_ds)
    plain = RandomForest(dataclasses.replace(p, hist_subtract=False),
                         num_trees=4, seed=9,
                         tree_batch=tree_batch).fit(skewed_ds)
    assert max(t.max_depth_reached for t in sub.trees) >= 3
    for t, (ta, tb) in enumerate(zip(sub.trees, plain.trees)):
        _assert_identical(ta, tb, f"{backend}/tb{tree_batch}/tree{t}")


@pytest.mark.parametrize("tree_batch", [1, 3])
def test_subtraction_survives_pruning(skewed_ds, tree_batch):
    """prune_closed_frac renumbers ROWS, not leaves: the carried tables
    stay valid and the pruned fit equals the unpruned one node-for-node
    (both with subtraction on, and each equal to the plain rebuild) —
    through the per-tree driver and the batched one."""
    p = tree_lib.TreeParams(max_depth=8, min_records=30, split_mode="hist",
                            num_bins=32)
    base = RandomForest(p, num_trees=3, seed=4,
                        tree_batch=tree_batch).fit(skewed_ds)
    pruned = RandomForest(dataclasses.replace(p, prune_closed_frac=0.25),
                          num_trees=3, seed=4,
                          tree_batch=tree_batch).fit(skewed_ds)
    plain_pruned = RandomForest(
        dataclasses.replace(p, prune_closed_frac=0.25, hist_subtract=False),
        num_trees=3, seed=4, tree_batch=tree_batch).fit(skewed_ds)
    for ta, tb, tc in zip(base.trees, pruned.trees, plain_pruned.trees):
        _assert_identical(ta, tb, f"tb{tree_batch}:pruned-vs-base")
        _assert_identical(tb, tc, f"tb{tree_batch}:sub-vs-plain")


def test_fast_path_one_level_program_per_depth(skewed_ds):
    """Subtraction keeps the one-batched-program-per-depth shape and never
    falls back to per-tree dispatches; warm refits do not retrace."""
    p = tree_lib.TreeParams(max_depth=5, split_mode="hist", num_bins=16)
    rf = RandomForest(p, num_trees=4, seed=0, tree_batch=4).fit(skewed_ds)
    calls0 = tree_lib._BATCH_STEP_CALLS[0]
    steps0 = tree_lib._STEP_CALLS[0]
    traces0 = tree_lib._BATCH_STEP_TRACES[0]
    rf2 = RandomForest(p, num_trees=4, seed=0, tree_batch=4).fit(skewed_ds)
    calls = tree_lib._BATCH_STEP_CALLS[0] - calls0
    D = max(t.max_depth_reached for t in rf2.trees)
    assert D <= calls <= p.max_depth + 1, (calls, D)
    assert tree_lib._STEP_CALLS[0] == steps0
    assert tree_lib._BATCH_STEP_TRACES[0] == traces0
    for ta, tb in zip(rf.trees, rf2.trees):
        _assert_identical(ta, tb, "warm-vs-cold")


def test_regression_forces_plain_rebuild():
    """Float regression tables cannot subtract exactly — the plan must
    rebuild plain (carries_tables False) while classification carries."""
    from repro.core.level.plan import make_plan
    ph = tree_lib.TreeParams(split_mode="hist", num_bins=16)
    plan_c = make_plan(ph, m_num=3, m_cat=0, max_arity=1, num_classes=2,
                       m_prime=2)
    assert plan_c.carries_tables and plan_c.use_bin_cuts
    pr = dataclasses.replace(ph, task="regression", impurity="variance")
    plan_r = make_plan(pr, m_num=3, m_cat=0, max_arity=1, num_classes=2,
                       m_prime=2)
    assert plan_r.use_bin_cuts and not plan_r.carries_tables
    po = dataclasses.replace(ph, hist_subtract=False)
    assert not make_plan(po, m_num=3, m_cat=0, max_arity=1, num_classes=2,
                         m_prime=2).carries_tables


# ---------------------------------------------------------------------------
# Fit-time validation of pre-quantized bucket state
# ---------------------------------------------------------------------------

def test_prequantized_num_bins_mismatch_raises(skewed_ds):
    bin_of, edges = skewed_ds.quantize(32)
    kw = dict(num=skewed_ds.num, cat=skewed_ds.cat, labels=skewed_ds.labels,
              sorted_vals=presort.gather_sorted(
                  skewed_ds.num, presort.presort_columns(skewed_ds.num)),
              sorted_idx=presort.presort_columns(skewed_ds.num),
              arities=skewed_ds.arities, num_classes=skewed_ds.num_classes,
              seed=0)
    p_bad = tree_lib.TreeParams(split_mode="hist", num_bins=64)
    with pytest.raises(ValueError, match="num_bins"):
        tree_lib.build_tree(params=p_bad, tree_idx=0, bin_of=bin_of,
                            bin_edges=edges, **kw)
    with pytest.raises(ValueError, match="num_bins"):
        tree_lib.build_forest(params=p_bad, tree_indices=range(2),
                              bin_of=bin_of, bin_edges=edges, **kw)
    # matching state passes (and equals the self-quantized fit)
    p_ok = tree_lib.TreeParams(split_mode="hist", num_bins=32, max_depth=3)
    ta, _ = tree_lib.build_tree(params=p_ok, tree_idx=0, bin_of=bin_of,
                                bin_edges=edges, **kw)
    tb, _ = tree_lib.build_tree(params=p_ok, tree_idx=0, **kw)
    _assert_identical(ta, tb, "prequantized-vs-self")
    # a bin cache too narrow for the bucket budget is rejected
    with pytest.raises(ValueError, match="dtype"):
        tree_lib.build_tree(
            params=tree_lib.TreeParams(split_mode="hist", num_bins=300),
            tree_idx=0,
            bin_of=jnp.zeros(bin_of.shape, jnp.uint8),
            bin_edges=jnp.zeros((bin_of.shape[0], 300), jnp.float32), **kw)
