"""Model-layer correctness: blocked attention, RWKV chunked-vs-recurrent,
Mamba scan-vs-decode, MoE dispatch, prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import layers, mamba as mamba_lib, moe as moe_lib, \
    rwkv as rwkv_lib, transformer
from repro.serve import engine


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_blocked_attention_matches_naive():
    cfg = _f32(get_arch("llama3-8b").reduced())
    key = jax.random.PRNGKey(0)
    p = layers.init_attention(key, cfg)
    B, S = 2, 128
    x = jax.random.normal(key, (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o_full, _ = layers.attention(p, x, cfg, pos, q_block=S)
    o_blk, _ = layers.attention(p, x, cfg, pos, q_block=16)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_blk),
                               atol=1e-4)


def test_sliding_window_attention_blocks_far_tokens():
    cfg = dataclasses.replace(_f32(get_arch("llava-next-mistral-7b").reduced()),
                              sliding_window=8)
    key = jax.random.PRNGKey(0)
    p = layers.init_attention(key, cfg)
    B, S = 1, 64
    x = jax.random.normal(key, (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1, _ = layers.attention(p, x, cfg, pos, q_block=16)
    # perturbing a token > window away must NOT change position t's output
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)
    o2, _ = layers.attention(p, x2, cfg, pos, q_block=16)
    np.testing.assert_allclose(np.asarray(o1[:, 20:]), np.asarray(o2[:, 20:]),
                               atol=1e-4)
    assert not np.allclose(np.asarray(o1[:, 2]), np.asarray(o2[:, 2]),
                           atol=1e-4)


def test_rope_styles():
    pos = jnp.arange(8)[None]
    sin, cos = layers.rope_angles(pos, 16, 1e4)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    full = layers.apply_rope(x, sin, cos, "full")
    half = layers.apply_rope(x, sin, cos, "half")
    # half (GLM 2d-RoPE) leaves the upper half of head dims untouched
    np.testing.assert_allclose(np.asarray(half[..., 8:]),
                               np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(full[..., 8:]), np.asarray(x[..., 8:]))
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(x[:, 0]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# RWKV6: chunked parallel == step-by-step recurrence
# ---------------------------------------------------------------------------

def test_rwkv_chunked_equals_recurrent():
    cfg = _f32(get_arch("rwkv6-7b").reduced())
    key = jax.random.PRNGKey(0)
    p = rwkv_lib.init_timemix(key, cfg)
    B, S = 2, 48
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    out_par, (xl, Sf) = rwkv_lib.timemix(p, x, cfg, chunk=16)
    # recurrent reference
    state = (jnp.zeros((B, cfg.d_model)),
             jnp.zeros((B, cfg.num_heads,
                        cfg.d_model // cfg.num_heads,
                        cfg.d_model // cfg.num_heads)))
    outs = []
    for t in range(S):
        o, state = rwkv_lib.timemix_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    out_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_par), np.asarray(out_rec),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(state[1]),
                               atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Mamba: full scan == token-by-token decode
# ---------------------------------------------------------------------------

def test_mamba_scan_equals_decode():
    cfg = _f32(get_arch("jamba-1.5-large-398b").reduced())
    key = jax.random.PRNGKey(0)
    p = mamba_lib.init_mamba(key, cfg)
    B, S = 2, 24
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    out_full, _ = mamba_lib.mamba(p, x, cfg)
    K = cfg.mamba_conv
    state = (jnp.zeros((B, K - 1, mamba_lib.d_inner(cfg))),
             jnp.zeros((B, mamba_lib.d_inner(cfg), cfg.mamba_d_state)))
    outs = []
    for t in range(S):
        o, state = mamba_lib.mamba_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_dec),
                               atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_reference():
    """With capacity_factor >> 1 no token drops: sort-based dispatch must
    equal the brute-force 'every expert on every token' weighted sum."""
    cfg = dataclasses.replace(_f32(get_arch("olmoe-1b-7b").reduced()),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    out, aux = moe_lib.moe_ffn(p, x, cfg)

    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate = gate / gate.sum(-1, keepdims=True)
    h1 = jnp.einsum("td,edf->tef", xf, p["we1"])
    h3 = jnp.einsum("td,edf->tef", xf, p["we3"])
    ye = jnp.einsum("tef,efd->ted", jax.nn.silu(h1) * h3, p["we2"])
    ref = jnp.zeros_like(xf)
    for k in range(cfg.num_experts_per_tok):
        sel = jnp.take_along_axis(ye, eidx[:, k][:, None, None], 1)[:, 0]
        ref = ref + sel * gate[:, k][:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=2e-3, rtol=1e-3)
    assert float(aux) >= 1.0 - 1e-3          # E[aux] == 1 at uniform routing


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(_f32(get_arch("olmoe-1b-7b").reduced()),
                              capacity_factor=0.25)
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, _ = moe_lib.moe_ffn(p, x, cfg)
    assert not bool(jnp.isnan(out).any())    # drops are zeros, not NaNs


# ---------------------------------------------------------------------------
# prefill -> decode consistency (the serving contract), per mixer family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_matches_full_forward(arch):
    cfg = _f32(get_arch(arch).reduced())
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = transformer.forward(params, toks, cfg)

    caches = transformer.init_cache(cfg, B, S)
    lens = jnp.zeros((B,), jnp.int32)
    outs = []
    for t in range(S):
        lg, caches = transformer.decode_step(params, caches, toks[:, t:t + 1],
                                             lens, cfg)
        lens = lens + 1
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(dec_logits), atol=5e-3, rtol=1e-2)


def test_prefill_then_decode_continues(arch="granite-3-2b"):
    cfg = _f32(get_arch(arch).reduced())
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, S = 1, 10
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    # teacher: full forward over S+1 tokens, logits at position S
    full_logits, _, _ = transformer.forward(params, toks, cfg)

    # prefill S tokens, decode token S
    logits_p, caches = engine.prefill_step(params, toks[:, :S], cfg)
    # prefill caches have length S; extend to S+1 for the decode write
    caches = jax.tree_util.tree_map(
        lambda c: jnp.concatenate(
            [c, jnp.zeros_like(c[:, :, :1])], axis=2)
        if c.ndim >= 3 and c.shape[2] == S else c, caches)
    lg, _ = transformer.decode_step(params, caches, toks[:, S:S + 1],
                                    jnp.full((B,), S, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(full_logits[:, S]),
                               np.asarray(lg[:, 0]), atol=5e-3, rtol=1e-2)
    # prefill's last-position logits match the full forward at S-1
    np.testing.assert_allclose(np.asarray(full_logits[:, S - 1]),
                               np.asarray(logits_p[:, 0]), atol=5e-3, rtol=1e-2)
