"""Fused level step (tree.build_tree) vs the reference builder, the Pallas
categorical path, and the stacked single-call forest predictor."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forest as forest_lib
from repro.core import presort, splits, tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.kernels import ops as kops


def _build_both(ds, params, seed=5, tree_idx=0, supersplit_fn=None):
    if ds.m_num:
        si = presort.presort_columns(ds.num)
        sv = presort.gather_sorted(ds.num, si)
    else:
        si = jnp.zeros((0, ds.n), jnp.int32)
        sv = jnp.zeros((0, ds.n), jnp.float32)
    kw = dict(num=ds.num, cat=ds.cat, labels=ds.labels, sorted_vals=sv,
              sorted_idx=si, arities=ds.arities, num_classes=ds.num_classes,
              params=params, seed=seed, tree_idx=tree_idx,
              supersplit_fn=supersplit_fn)
    fused, _ = tree_lib.build_tree(**kw)
    ref, _ = tree_lib.build_tree_reference(**kw)
    return fused, ref


def _assert_identical(ta, tb):
    """Bit-identical flat trees: splits, thresholds, masks, leaf values."""
    assert ta.num_nodes == tb.num_nodes
    np.testing.assert_array_equal(ta.feature, tb.feature)
    np.testing.assert_array_equal(ta.children, tb.children)
    np.testing.assert_array_equal(ta.threshold, tb.threshold)
    np.testing.assert_array_equal(ta.is_cat, tb.is_cat)
    np.testing.assert_array_equal(ta.cat_mask, tb.cat_mask)
    np.testing.assert_array_equal(ta.value, tb.value)
    np.testing.assert_array_equal(ta.n_node, tb.n_node)
    np.testing.assert_array_equal(ta.gain, tb.gain)
    np.testing.assert_array_equal(ta.depth, tb.depth)


@pytest.fixture(scope="module")
def mixed_ds():
    rng = np.random.default_rng(3)
    n = 1100
    num = rng.normal(size=(n, 4)).astype(np.float32)
    cat = rng.integers(0, 5, size=(n, 2)).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 0] >= 3)).astype(np.int32)
    return from_numpy(num, cat, y)


@pytest.mark.parametrize("backend", ["segment", "scan", "kernel"])
def test_fused_matches_reference_classification_mixed(mixed_ds, backend):
    p = tree_lib.TreeParams(max_depth=4, backend=backend)
    _assert_identical(*_build_both(mixed_ds, p))


@pytest.mark.parametrize("backend", ["segment", "scan"])
def test_fused_matches_reference_regression(backend):
    rng = np.random.default_rng(1)
    n = 900
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    ds = from_numpy(num, None, y, task="regression")
    p = tree_lib.TreeParams(max_depth=5, backend=backend,
                            impurity="variance", task="regression",
                            bagging="none")
    _assert_identical(*_build_both(ds, p, seed=2))


def test_fused_matches_reference_pure_categorical():
    rng = np.random.default_rng(0)
    n = 700
    cat = rng.integers(0, 6, size=(n, 3)).astype(np.int32)
    y = ((cat[:, 0] % 2) ^ (cat[:, 1] >= 3)).astype(np.int32)
    ds = from_numpy(None, cat, y)
    p = tree_lib.TreeParams(max_depth=4)
    _assert_identical(*_build_both(ds, p))


def test_fused_matches_reference_deeper_multiclass():
    """More levels (several leaf paddings) + 3 classes + entropy."""
    rng = np.random.default_rng(7)
    n = 2000
    num = rng.normal(size=(n, 5)).astype(np.float32)
    y = (np.digitize(num[:, 0] + num[:, 1], [-0.6, 0.6])).astype(np.int32)
    ds = from_numpy(num, None, y)
    p = tree_lib.TreeParams(max_depth=7, min_records=2, impurity="entropy")
    _assert_identical(*_build_both(ds, p, seed=9))


# ---------------------------------------------------------------------------
# Pallas cat_hist-backed categorical supersplit vs the jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,bv", [
    (6, 4),        # arity NOT a multiple of bv -> padded category blocks
    (16, 4),       # exact multiple
    (37, 8),       # high-ish arity, non-multiple
    (130, 32),     # > one lane group, non-multiple
])
def test_kernel_categorical_path_matches_reference(V, bv):
    rng = np.random.default_rng(V)
    n, m, L, C = 640, 3, 4, 3
    x = rng.integers(0, V, size=(n, m)).astype(np.int32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C,
                             "classification")
    cand = np.ones((m, L + 1), bool)
    cand[:, 0] = False

    tables = kops.categorical_tables(
        jnp.asarray(x.T), jnp.asarray(leaf), jnp.asarray(w),
        jnp.asarray(y), V=V, Lp=L, bv=bv, num_classes=C)
    assert tables.shape == (m, L + 1, V, C)
    for j in range(m):
        g_k, m_k = splits.best_categorical_split_from_table(
            tables[j], jnp.asarray(cand[j]))
        g_r, m_r = splits.best_categorical_split(
            jnp.asarray(x[:, j]), jnp.asarray(leaf), jnp.asarray(w), stats,
            jnp.asarray(cand[j]), L, V)
        fin = np.isfinite(np.asarray(g_r))
        assert (np.isfinite(np.asarray(g_k)) == fin).all()
        np.testing.assert_allclose(np.asarray(g_k)[fin],
                                   np.asarray(g_r)[fin], atol=1e-4, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(m_k)[fin],
                                      np.asarray(m_r)[fin])


def test_fused_kernel_backend_with_high_arity_categoricals():
    """End-to-end: fused builder, kernel backend, arity not a bv multiple."""
    rng = np.random.default_rng(4)
    n = 600
    num = rng.normal(size=(n, 2)).astype(np.float32)
    cat = np.stack([rng.integers(0, 7, n), rng.integers(0, 13, n)], 1).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 1] >= 6)).astype(np.int32)
    ds = from_numpy(num, cat, y)
    p = tree_lib.TreeParams(max_depth=3, backend="kernel")
    _assert_identical(*_build_both(ds, p))


# ---------------------------------------------------------------------------
# Stacked forest inference: one jitted call, no per-tree retrace
# ---------------------------------------------------------------------------

def test_predict_proba_single_jitted_call_100_trees(mixed_ds):
    rf = RandomForest(tree_lib.TreeParams(max_depth=3), num_trees=100,
                      seed=0).fit(mixed_ds)
    assert rf.packed is not None and rf.packed.num_trees == 100

    calls = []
    orig = forest_lib._forest_predict

    def counting(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    forest_lib._forest_predict = counting
    try:
        # the per-tree path must be gone entirely
        def boom(*a, **k):
            raise AssertionError("per-tree _predict_jit used by predict_proba")
        saved = tree_lib._predict_jit
        tree_lib._predict_jit = boom
        try:
            traces0 = forest_lib._PREDICT_TRACES[0]
            p1 = rf.predict_proba(mixed_ds.num, mixed_ds.cat)
            assert len(calls) == 1                    # exactly one jitted call
            assert forest_lib._PREDICT_TRACES[0] - traces0 <= 1  # one trace
            p2 = rf.predict_proba(mixed_ds.num, mixed_ds.cat)
            assert len(calls) == 2
            assert forest_lib._PREDICT_TRACES[0] - traces0 <= 1  # no retrace
        finally:
            tree_lib._predict_jit = saved
    finally:
        forest_lib._forest_predict = orig

    # parity with the per-tree evaluator
    acc = None
    for tr in rf.trees:
        p = np.asarray(tr.predict_raw(mixed_ds.num, mixed_ds.cat))
        acc = p if acc is None else acc + p
    np.testing.assert_allclose(np.asarray(p1), acc / len(rf.trees), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_predict_proba_up_to_prefix(mixed_ds):
    rf = RandomForest(tree_lib.TreeParams(max_depth=3), num_trees=6,
                      seed=1).fit(mixed_ds)
    p3 = np.asarray(rf.predict_proba(mixed_ds.num, mixed_ds.cat, up_to=3))
    acc = None
    for tr in rf.trees[:3]:
        p = np.asarray(tr.predict_raw(mixed_ds.num, mixed_ds.cat))
        acc = p if acc is None else acc + p
    np.testing.assert_allclose(p3, acc / 3, atol=1e-6)
