"""Exact-split search vs a brute-force oracle — the suite that certifies
"exact" (the paper's central claim) for every numeric engine and the
categorical table scorer.

The oracle is a tiny O(n·S) numpy implementation: per leaf, sort the
in-bag rows once, sweep cumulative histograms over the boundaries between
consecutive distinct values, and keep the first-best boundary (the
engines' scan-order tie-break).  Deterministic seed-parametrized cases run
in tier-1 (no hypothesis needed); the `-m hypothesis` sweep drives the same
checker from `@given` seeds under the fixed derandomized profile
(tests/conftest.py).

Adversarial structure baked into every generated dataset: duplicated
values (ties), a constant column, a single-class leaf, zero-weight
(bagged-out) rows, a fully bagged-out leaf, and closed (leaf 0) rows.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import splits
from repro.kernels import ops as kops

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# The oracle
# ---------------------------------------------------------------------------

def _imp(h, impurity):
    """Weighted (N·) impurity of histogram(s) h (..., S), float64."""
    h = np.asarray(h, np.float64)
    n = h.sum(-1)
    if impurity == "gini":
        return n - np.divide((h * h).sum(-1), n, out=np.zeros_like(n),
                             where=n > 0)
    if impurity == "entropy":
        p = np.divide(h, n[..., None], out=np.zeros_like(h),
                      where=n[..., None] > 0)
        plogp = np.where(h > 0, p * np.log(np.maximum(p, 1e-300)), 0.0)
        return -(n * plogp.sum(-1))
    if impurity == "variance":
        w, wy, wy2 = h[..., 0], h[..., 1], h[..., 2]
        return np.maximum(wy2 - np.divide(wy * wy, w, out=np.zeros_like(w),
                                          where=w > 0), 0.0)
    raise ValueError(impurity)


def _row_stats_np(y, w, C, task):
    if task == "classification":
        s = np.zeros((len(y), C), np.float64)
        s[np.arange(len(y)), y] = w
        return s
    y = np.asarray(y, np.float64)
    return np.stack([w, w * y, w * y * y], -1)


def oracle_numeric(vals, y, w, C, impurity="gini", task="classification",
                   min_records=1.0):
    """Best (gain, threshold) for ONE leaf's rows, O(n·S).

    One sort, then a cumulative-histogram sweep over the midpoints between
    consecutive distinct in-bag values; first boundary wins ties (the
    engines' scan order).  Returns (-inf, 0.0) when no valid split exists.
    """
    inb = w > 0
    vals, y, w = vals[inb], y[inb], w[inb]
    if len(vals) < 2:
        return -np.inf, 0.0
    order = np.argsort(vals, kind="stable")
    vals, stats = vals[order], _row_stats_np(y[order], w[order], C, task)
    total = stats.sum(0)
    prefix = np.cumsum(stats, 0)                   # left of cut after row k
    cnt = (lambda h: h.sum(-1)) if task == "classification" \
        else (lambda h: h[..., 0])
    best_g, best_t = -np.inf, 0.0
    for k in range(len(vals) - 1):
        if vals[k + 1] <= vals[k]:
            continue                               # not a distinct boundary
        left, right = prefix[k], total - prefix[k]
        if cnt(left) < min_records or cnt(right) < min_records:
            continue
        g = (_imp(total, impurity) - _imp(left, impurity)
             - _imp(right, impurity))
        if g > best_g:                             # strict: first max wins
            best_g = g
            best_t = (float(vals[k]) + float(vals[k + 1])) / 2.0
    return best_g, best_t


def oracle_gain_at(vals, y, w, C, thr, impurity="gini",
                   task="classification"):
    """Gain of the partition (x <= thr) for one leaf's in-bag rows."""
    inb = w > 0
    vals, y, w = vals[inb], y[inb], w[inb]
    stats = _row_stats_np(y, w, C, task)
    left = stats[vals <= thr].sum(0)
    right = stats[vals > thr].sum(0)
    return (_imp(left + right, impurity) - _imp(left, impurity)
            - _imp(right, impurity))


# ---------------------------------------------------------------------------
# Adversarial dataset generator (shared by deterministic + hypothesis runs)
# ---------------------------------------------------------------------------

def make_case(seed, n=260, L=4, C=3, m=3):
    """Random (num (n, m), leaf, w, y) with every edge case baked in:
    column 0 tied (coarse grid), column 1 CONSTANT, leaf 1 single-class,
    leaf 2 fully bagged out, plus closed rows (leaf 0) and w == 0 rows."""
    rng = np.random.default_rng(seed)
    num = rng.normal(size=(n, m)).astype(np.float32)
    num[:, 0] = np.round(num[:, 0] * 2) / 2        # heavy ties
    num[:, 1] = 1.5                                # constant column
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)   # zero-weight rows
    y = rng.integers(0, C, n).astype(np.int32)
    y[leaf == 1] = C - 1                           # single-class leaf
    w[leaf == 2] = 0.0                             # fully bagged-out leaf
    return num, leaf, w, y


def _engine_supersplit(backend, num, leaf, w, y, C, Lp, impurity,
                       min_records, task="classification"):
    """Run one numeric engine over all columns; returns (m, L+1) g / t."""
    labels = y.astype(np.float32) if task == "regression" else y
    stats = splits.row_stats(jnp.asarray(labels), jnp.asarray(w), C, task)
    m = num.shape[1]
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = np.take_along_axis(num.T, si, -1)
    cand = np.ones((m, Lp + 1), bool)
    cand[:, 0] = False

    if backend == "kernel":
        g, t = kops.split_scan_supersplit(
            jnp.asarray(sv), jnp.asarray(si), jnp.asarray(leaf),
            jnp.asarray(w), jnp.asarray(labels), jnp.asarray(cand), Lp,
            impurity, task, min_records, num_classes=C)
        return np.asarray(g), np.asarray(t)
    if backend == "leaf_ordered":
        ord_idx = np.stack([np.argsort(leaf[si[j]], kind="stable")
                            for j in range(m)])
        ord_idx = np.take_along_axis(si, ord_idx, -1)   # (leaf, value) order
        lf_pos = leaf[ord_idx[0]]
        inbag = (w > 0)[ord_idx] & (lf_pos > 0)[None]
        vals = np.take_along_axis(num.T, ord_idx, -1)
        row_counts = np.bincount(lf_pos, minlength=Lp + 1).astype(np.int32)
        g, t = splits.best_numeric_split_leaf_ordered(
            jnp.asarray(vals), jnp.asarray(lf_pos), jnp.asarray(inbag),
            stats[jnp.asarray(ord_idx)], jnp.asarray(cand), Lp, impurity,
            task, min_records, totals=None,
            row_counts=jnp.asarray(row_counts))
        return np.asarray(g), np.asarray(t)

    fn = splits.NUMERIC_BACKENDS[backend]

    def per_col(j):
        s = si[j]
        return fn(jnp.asarray(sv[j]), jnp.asarray(leaf[s]),
                  jnp.asarray(w[s]), stats[jnp.asarray(s)],
                  jnp.asarray(cand[j]), Lp, impurity, task, min_records)
    outs = [per_col(j) for j in range(m)]
    return (np.stack([np.asarray(g) for g, _ in outs]),
            np.stack([np.asarray(t) for _, t in outs]))


ALL_ENGINES = ["scan", "segment", "leaf_ordered", "kernel"]


def check_against_oracle(backend, seed, impurity="gini", min_records=1.0):
    num, leaf, w, y = make_case(seed)
    L, C = int(leaf.max()), int(y.max()) + 1
    if L == 0:
        return
    g, t = _engine_supersplit(backend, num, leaf, w, y, C, L, impurity,
                              min_records)
    for j in range(num.shape[1]):
        for h in range(1, L + 1):
            sel = leaf == h
            bg, _ = oracle_numeric(num[sel, j], y[sel], w[sel], C,
                                   impurity, min_records=min_records)
            ctx = f"{backend}/seed{seed}/col{j}/leaf{h}"
            if not np.isfinite(bg):
                assert not np.isfinite(g[j, h]), ctx
                continue
            assert np.isfinite(g[j, h]), ctx
            np.testing.assert_allclose(g[j, h], bg, rtol=1e-4, atol=1e-4,
                                       err_msg=ctx)
            # tie-robust threshold check: the engine's threshold must
            # ACHIEVE the oracle's best gain (equal-gain boundaries may
            # legitimately differ in the last ulp of the gain comparison)
            ga = oracle_gain_at(num[sel, j], y[sel], w[sel], C, t[j, h],
                                impurity)
            np.testing.assert_allclose(ga, bg, rtol=1e-4, atol=1e-4,
                                       err_msg=ctx + "/thr")
            # and must separate two observed in-bag values
            iv = num[sel & (w > 0), j]
            assert iv.min() <= t[j, h] < iv.max(), ctx


# ---------------------------------------------------------------------------
# Deterministic tier-1 oracle coverage (no hypothesis required)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_ENGINES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_numeric_engines_match_oracle(backend, seed):
    check_against_oracle(backend, seed)


@pytest.mark.parametrize("backend", ALL_ENGINES)
def test_numeric_engines_match_oracle_entropy_min_records(backend):
    check_against_oracle(backend, 7, impurity="entropy", min_records=5.0)


@pytest.mark.parametrize("backend", ["scan", "segment", "leaf_ordered",
                                     "kernel"])
def test_regression_engines_match_oracle(backend):
    rng = np.random.default_rng(11)
    n, L = 220, 3
    num = rng.normal(size=(n, 2)).astype(np.float32)
    num[:, 0] = np.round(num[:, 0] * 2) / 2
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = (num[:, 0] * 2 + rng.normal(size=n) * 0.3).astype(np.float32)
    g, t = _engine_supersplit(num=num, leaf=leaf, w=w, y=y, C=2, Lp=L,
                              backend=backend, impurity="variance",
                              min_records=1.0, task="regression")
    for j in range(2):
        for h in range(1, L + 1):
            sel = leaf == h
            bg, _ = oracle_numeric(num[sel, j], y[sel], w[sel], 2,
                                   "variance", "regression")
            ctx = f"{backend}/col{j}/leaf{h}"
            if not np.isfinite(bg):
                assert not np.isfinite(g[j, h]), ctx
                continue
            np.testing.assert_allclose(g[j, h], bg, rtol=1e-3, atol=1e-3,
                                       err_msg=ctx)
            ga = oracle_gain_at(num[sel, j], y[sel], w[sel], 2, t[j, h],
                                "variance", "regression")
            np.testing.assert_allclose(ga, bg, rtol=1e-3, atol=1e-3,
                                       err_msg=ctx + "/thr")


def test_categorical_table_scorer_binary_exhaustive():
    """Binary classification, small arity: the Breiman-ordered prefix cuts
    must find the best of ALL 2^(V-1) subsets — checked from the same
    count-table input the fused step feeds the scorer."""
    for seed in (0, 3):
        rng = np.random.default_rng(seed)
        n, L, V = 300, 3, 5
        x = rng.integers(0, V, n).astype(np.int32)
        leaf = rng.integers(0, L + 1, n).astype(np.int32)
        w = rng.integers(0, 3, n).astype(np.float32)
        y = rng.integers(0, 2, n).astype(np.int32)
        y[leaf == 1] = 1                              # single-class leaf
        stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), 2,
                                 "classification")
        table = splits.categorical_count_table(
            jnp.asarray(x), jnp.asarray(leaf), jnp.asarray(w), stats, L, V)
        cand = jnp.asarray([False] + [True] * L)
        g, mask = splits.best_categorical_split_from_table(table, cand)
        g, mask = np.asarray(g), np.asarray(mask)
        tb = np.asarray(table, np.float64)
        for h in range(1, L + 1):
            total = tb[h].sum(0)
            best = -np.inf
            for subset in range(1, 2 ** V - 1):
                in_s = np.array([(subset >> v) & 1 for v in range(V)], bool)
                hl = tb[h][in_s].sum(0)
                hr = total - hl
                if hl.sum() < 1 or hr.sum() < 1:
                    continue
                best = max(best, _imp(total, "gini") - _imp(hl, "gini")
                           - _imp(hr, "gini"))
            ctx = f"seed{seed}/leaf{h}"
            if not np.isfinite(best):
                assert not np.isfinite(g[h]), ctx
                continue
            np.testing.assert_allclose(g[h], best, rtol=1e-4, atol=1e-4,
                                       err_msg=ctx)
            # the reported mask must achieve the reported gain
            hl = tb[h][mask[h]].sum(0)
            gm = (_imp(total, "gini") - _imp(hl, "gini")
                  - _imp(total - hl, "gini"))
            np.testing.assert_allclose(gm, best, rtol=1e-4, atol=1e-4,
                                       err_msg=ctx + "/mask")


def test_oracle_on_degenerate_leaves():
    """Constant column / single distinct value / all-zero weights -> -inf."""
    for backend in ALL_ENGINES:
        num = np.full((40, 1), 2.5, np.float32)
        leaf = np.ones(40, np.int32)
        w = np.ones(40, np.float32)
        y = np.arange(40, dtype=np.int64).astype(np.int32) % 2
        g, _ = _engine_supersplit(backend, num, leaf, w, y, 2, 1, "gini", 1.0)
        assert not np.isfinite(g[0, 1]), backend
        w0 = np.zeros(40, np.float32)
        g, _ = _engine_supersplit(backend, num, leaf, w0, y, 2, 1, "gini", 1.0)
        assert not np.isfinite(g[0, 1]), backend


# ---------------------------------------------------------------------------
# Streamed histogram accumulation vs the brute-force oracle
# (the out-of-core path: tables built chunk by chunk, DESIGN.md §8)
# ---------------------------------------------------------------------------

def test_hist_scorer_on_streamed_tables_matches_oracle():
    """Hist-mode scoring from CHUNK-ACCUMULATED count tables equals the
    numpy oracle restricted to bucket-boundary thresholds — the same
    adversarial cases (ties, constant column, bagged-out leaf) as the
    exact engines, with the tables built over uneven chunk boundaries
    exactly like `build_forest_streamed` builds them."""
    from repro.core import presort
    B = 16
    for seed in (0, 5):
        num, leaf, w, y = make_case(seed)
        n, m = num.shape
        L, C = int(leaf.max()), int(y.max()) + 1
        si = presort.presort_columns(jnp.asarray(num))
        sv = presort.gather_sorted(jnp.asarray(num), si)
        edges = np.asarray(presort.quantize_edges(sv, B))
        bins = presort.bin_block(num, edges)               # (m, n)
        stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C,
                                 "classification")
        table = np.zeros((m, L + 1, B, C), np.float32)
        for lo in range(0, n, 83):                         # uneven tail
            hi = min(lo + 83, n)
            table += np.asarray(splits.feature_count_tables(
                jnp.asarray(np.ascontiguousarray(bins[:, lo:hi])),
                jnp.asarray(leaf[lo:hi]), jnp.asarray(w[lo:hi]),
                stats[lo:hi], L, B))
        cand = jnp.asarray([False] + [True] * L)
        for j in range(m):
            g, cut = splits.best_numeric_split_histogram(
                jnp.asarray(table[j]), cand)
            g, cut = np.asarray(g), np.asarray(cut)
            for h in range(1, L + 1):
                sel = leaf == h
                vj, yj, wj = num[sel, j], y[sel], w[sel]
                best = -np.inf
                for b in range(B - 1):                     # boundary sweep
                    thr = edges[j, b]
                    nl = wj[(vj <= thr) & (wj > 0)].sum()
                    nr = wj[(vj > thr) & (wj > 0)].sum()
                    if nl < 1 or nr < 1:
                        continue
                    gb = oracle_gain_at(vj, yj, wj, C, thr)
                    if gb > best:                          # first max wins
                        best = gb
                ctx = f"seed{seed}/col{j}/leaf{h}"
                if not np.isfinite(best):
                    assert not np.isfinite(g[h]), ctx
                    continue
                assert np.isfinite(g[h]), ctx
                np.testing.assert_allclose(g[h], best, rtol=1e-4,
                                           atol=1e-4, err_msg=ctx)
                # the decoded float threshold reproduces the scored
                # partition (bin <= b  <=>  x <= edges[b])
                ga = oracle_gain_at(vj, yj, wj, C, edges[j, int(cut[h])])
                np.testing.assert_allclose(ga, best, rtol=1e-4, atol=1e-4,
                                           err_msg=ctx + "/thr")


# ---------------------------------------------------------------------------
# Whole-tree oracle: a recursive numpy reference builder vs build_tree
# (ROADMAP "Exact-oracle suite follow-up")
# ---------------------------------------------------------------------------

def _np_bag_counts(seed, tree_idx, n, mode):
    """The seeded bootstrap weights as numpy (the draw itself is pinned by
    the deterministic PRNG; the oracle consumes, never re-derives it)."""
    from repro.core import bagging
    return np.asarray(bagging.bag_counts(seed, tree_idx, n, mode))


def _np_candidates(seed, tree_idx, depth, num_leaves, m, m_prime):
    """Per-leaf candidate masks as numpy (padding-independent draw)."""
    import jax
    from repro.core import bagging
    fkey = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), tree_idx)
    return np.asarray(bagging.candidate_features(
        fkey, depth, num_leaves, m, m_prime))


class _RefTree:
    """Flat arrays grown by the reference builder (mirrors tree_lib.Tree)."""

    def __init__(self, C):
        self.feature, self.threshold, self.children = [], [], []
        self.value, self.n_node, self.gain, self.depth = [], [], [], []
        self._C = C

    def new_node(self, depth):
        self.feature.append(-1)
        self.threshold.append(np.float32(0.0))
        self.children.append([-1, -1])
        self.value.append(np.zeros(self._C, np.float32))
        self.n_node.append(0.0)
        self.gain.append(0.0)
        self.depth.append(depth)
        return len(self.feature) - 1


def build_tree_oracle(num, y, params, seed, tree_idx, C):
    """Recursive (level-recursion) numpy reference builder for EXACT mode.

    sklearn-style per-node exhaustive search — every candidate feature of
    every open leaf is scored by the O(n·S) `oracle_numeric` sweep, the
    first-best feature wins (the engines' argmax order), children are
    numbered left-to-right in leaf order — with zero shared code with the
    jitted engines beyond the seeded draws it consumes.  Numeric-only
    datasets (the categorical scorer has its own exhaustive oracle above).
    """
    n, m = num.shape
    task, imp = params.task, params.impurity
    m_prime = params.num_candidates or max(
        1, int(np.ceil(np.sqrt(m))))
    w = _np_bag_counts(seed, tree_idx, n, params.bagging)
    ref = _RefTree(max(C, 2) if task == "classification" else 1)
    root = ref.new_node(0)

    def node_value(node, rows):
        stats = _row_stats_np(y[rows], w[rows], C, task).sum(0)
        cnt = stats.sum() if task == "classification" else stats[0]
        ref.n_node[node] = float(cnt)
        if task == "classification":
            ref.value[node] = (stats.astype(np.float32)
                               / np.float32(max(cnt, 1e-12)))
        else:
            ref.value[node] = np.array(
                [stats[1] / max(stats[0], 1e-12)], np.float32)
        return cnt

    def grow(frontier, depth):
        """One level: frontier = [(node id, row mask)] in leaf order."""
        if not frontier:
            return
        counts = [node_value(node, rows) for node, rows in frontier]
        if depth >= params.max_depth:
            return
        cand = _np_candidates(seed, tree_idx, depth, len(frontier), m,
                              m_prime)
        next_frontier = []
        for h, (node, rows) in enumerate(frontier):
            if counts[h] < 2 * params.min_records:
                continue
            best_g, best_j, best_t = -np.inf, None, 0.0
            for j in range(m):
                if not cand[h, j]:
                    continue
                g, t = oracle_numeric(num[rows, j], y[rows], w[rows], C,
                                      imp, task, params.min_records)
                if g > best_g:                     # first feature wins ties
                    best_g, best_j, best_t = g, j, t
            if best_j is None or not np.isfinite(best_g) or best_g <= 1e-9:
                continue
            # the engines compute tau = (a + v) * 0.5 in float32
            iv = np.sort(num[rows & (w > 0), best_j].astype(np.float32))
            lo = iv[iv <= best_t].max()
            hi = iv[iv > best_t].min()
            thr = (lo + hi) * np.float32(0.5)
            ref.feature[node] = best_j
            ref.gain[node] = float(best_g)
            ref.threshold[node] = thr
            lc = ref.new_node(depth + 1)
            rc = ref.new_node(depth + 1)
            ref.children[node] = [lc, rc]
            next_frontier.append((lc, rows & (num[:, best_j] <= thr)))
            next_frontier.append((rc, rows & (num[:, best_j] > thr)))
        grow(next_frontier, depth + 1)

    grow([(root, np.ones(n, bool))], 0)
    return ref


def _fitted_tree(num, y, params, seed, tree_idx, task):
    from repro.core import presort, tree as tree_lib
    from repro.core.dataset import from_numpy
    ds = from_numpy(num, None, y,
                    task="regression" if task == "regression" else
                    "classification")
    si = presort.presort_columns(ds.num)
    sv = presort.gather_sorted(ds.num, si)
    tr, _ = tree_lib.build_tree(
        num=ds.num, cat=ds.cat, labels=ds.labels, sorted_vals=sv,
        sorted_idx=si, arities=ds.arities, num_classes=ds.num_classes,
        params=params, seed=seed, tree_idx=tree_idx)
    return tr, ds.num_classes


def _assert_tree_matches_oracle(tr, ref, task, ctx):
    assert tr.num_nodes == len(ref.feature), ctx
    np.testing.assert_array_equal(tr.feature, ref.feature, err_msg=ctx)
    np.testing.assert_array_equal(tr.children, ref.children, err_msg=ctx)
    np.testing.assert_array_equal(tr.depth, ref.depth, err_msg=ctx)
    np.testing.assert_array_equal(tr.threshold,
                                  np.asarray(ref.threshold, np.float32),
                                  err_msg=ctx)
    np.testing.assert_allclose(tr.gain, ref.gain, rtol=1e-4, atol=1e-4,
                               err_msg=ctx)
    np.testing.assert_allclose(tr.n_node, ref.n_node, rtol=0, atol=0,
                               err_msg=ctx)
    np.testing.assert_allclose(tr.value, np.stack(ref.value),
                               rtol=1e-6, atol=1e-6, err_msg=ctx)


@pytest.mark.parametrize("backend", ["segment", "scan"])
@pytest.mark.parametrize("seed", [0, 4])
def test_whole_tree_matches_recursive_oracle_classification(backend, seed):
    """Node-for-node equality of build_tree against the recursive numpy
    reference on a small continuous classification dataset."""
    from repro.core import tree as tree_lib
    rng = np.random.default_rng(seed)
    n, m, C = 400, 5, 3
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = np.digitize(num[:, 0] + 0.7 * num[:, 1],
                    [-0.5, 0.5]).astype(np.int32)
    params = tree_lib.TreeParams(max_depth=4, min_records=3,
                                 backend=backend)
    tr, C_ds = _fitted_tree(num, y, params, seed=11, tree_idx=seed,
                            task="classification")
    ref = build_tree_oracle(num, y, params, seed=11, tree_idx=seed, C=C_ds)
    _assert_tree_matches_oracle(tr, ref, "classification",
                                f"{backend}/seed{seed}")


def test_whole_tree_matches_recursive_oracle_regression():
    from repro.core import tree as tree_lib
    rng = np.random.default_rng(2)
    n, m = 350, 4
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    params = tree_lib.TreeParams(max_depth=4, min_records=4,
                                 impurity="variance", task="regression",
                                 bagging="none")
    tr, _ = _fitted_tree(num, y, params, seed=5, tree_idx=0,
                         task="regression")
    ref = build_tree_oracle(num, y, params, seed=5, tree_idx=0, C=2)
    # float32 device sums vs float64 numpy sums: structure exact, float
    # leaf statistics to tolerance
    assert tr.num_nodes == len(ref.feature)
    np.testing.assert_array_equal(tr.feature, ref.feature)
    np.testing.assert_array_equal(tr.children, ref.children)
    np.testing.assert_array_equal(tr.depth, ref.depth)
    np.testing.assert_allclose(tr.threshold, ref.threshold,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(tr.gain, ref.gain, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(tr.value, np.stack(ref.value),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweep (pytest -m hypothesis; fixed profile in conftest.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @given(st.integers(0, 10_000),
           st.sampled_from(ALL_ENGINES),
           st.sampled_from(["gini", "entropy"]),
           st.sampled_from([1.0, 4.0]))
    def test_property_numeric_engines_match_oracle(seed, backend, impurity,
                                                   min_records):
        check_against_oracle(backend, seed, impurity, min_records)
