"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see
the real single CPU device; only launch/dryrun.py and the subprocess-based
distributed tests force a multi-device host platform."""
import numpy as np
import pytest

try:
    # Fixed deterministic hypothesis profile for the property/oracle suites
    # (pytest -m hypothesis): derandomized so a run is reproducible in CI,
    # no deadline (jit compiles inside test bodies), no example database
    # (state on disk would make runs order-dependent).
    import hypothesis

    hypothesis.settings.register_profile(
        "repro", derandomize=True, deadline=None, max_examples=50,
        database=None)
    hypothesis.settings.load_profile("repro")
except ImportError:        # optional dev dependency (DESIGN.md §Testing)
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
