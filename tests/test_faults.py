"""Fault-tolerance suite: kill it, resume it, get the SAME forest.

DESIGN.md §9's contract, asserted three ways:

* retried transient reads never change the trained forest (reads are
  pure, so a retry is byte-identical — deterministic sweep here plus a
  hypothesis sweep over random fault schedules);
* a persistent read failure flushes the held level checkpoint BEFORE
  `StreamReadError` escapes, and resuming from that checkpoint
  finishes the forest node-for-node bit-identical to an uninterrupted
  fit — including mid-forest (completed tree batches are skipped, the
  in-flight one restarts at its last snapshotted level);
* SIGKILL — at a scheduled read, after a chosen snapshot, or in the
  worst window of an atomic write (tmp written, `os.replace` pending)
  — loses at most the uncommitted levels: the subprocess kill tests
  (`-m faults`) resume in the parent and assert bit-identity, for
  in-memory and memmap sources with Sprint pruning on.

Also here: `PackedForest.save` atomicity, `MemmapRowSource` sidecar
integrity (`CacheIntegrityError`), and checkpoint fingerprint
validation (`CheckpointMismatchError`).
"""
import json
import logging
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import atomicio, checkpoint, dataset, tree as tree_lib
from repro.core.dataset import (ArrayRowSource, CacheIntegrityError,
                                MemmapRowSource, StreamReadError)
from repro.core.forest import PackedForest, RandomForest
from repro.data.synthetic import make_tabular
from repro.testing import faults
from repro.testing.faults import FaultyRowSource

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FIELDS = ("feature", "children", "threshold", "is_cat", "cat_mask",
          "value", "n_node", "gain", "depth")


def _assert_forests_identical(fa, fb, ctx=""):
    assert len(fa.trees) == len(fb.trees), ctx
    for t, (ta, tb) in enumerate(zip(fa.trees, fb.trees)):
        assert ta.num_nodes == tb.num_nodes, f"{ctx}/tree{t}: node count"
        for f in FIELDS:
            np.testing.assert_array_equal(getattr(ta, f), getattr(tb, f),
                                          err_msg=f"{ctx}/tree{t}: {f}")
    # node-identity implies prediction-identity; check the packed path too
    pa, pb = fa._packed_forest(), fb._packed_forest()
    x = np.linspace(-2, 2, 32 * pa.m_num).reshape(32, pa.m_num)
    np.testing.assert_array_equal(
        np.asarray(pa.predict_proba(x, np.zeros((32, 0), np.int32))),
        np.asarray(pb.predict_proba(x, np.zeros((32, 0), np.int32))),
        err_msg=f"{ctx}: packed predict")


@pytest.fixture(scope="module")
def setup():
    """Streamed reference fit (pruning ON) + its source and params."""
    ds = make_tabular("xor", n=600, num_informative=4, num_useless=2,
                      seed=3)
    params = tree_lib.TreeParams(max_depth=5, split_mode="hist",
                                 num_bins=16, prune_closed_frac=0.3)
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=149)
    ref = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(src)
    return ds, params, src, ref


@pytest.fixture(autouse=True)
def _disarm_hooks():
    yield
    faults.disarm()


# ---------------------------------------------------------------------------
# Checkpointed fit: parity, cadence, manifest lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("every", [1, 2])
def test_checkpointed_fit_parity_and_cadence(setup, tmp_path, every):
    """An uninterrupted checkpointed fit trains the identical forest,
    snapshots on the `checkpoint_every` cadence, and commits the batch
    (manifest entry + trees file, snapshot dropped)."""
    _, params, src, ref = setup
    depths = []
    checkpoint.POST_SNAPSHOT_HOOK[0] = lambda depth, path: depths.append(depth)
    ck = tmp_path / f"ck{every}"
    fc = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=str(ck), checkpoint_every=every)
    _assert_forests_identical(ref, fc, f"every{every}")
    assert depths, "no snapshots were written"
    assert all((d + 1) % every == 0 for d in depths), depths
    with open(ck / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["batches"]["0-2"]["tree_indices"] == [0, 1, 2]
    assert (ck / "trees_0-2.npz").exists()
    assert not (ck / "snap_0-2.npz").exists()    # dropped on commit
    assert not list(ck.glob("*.tmp.*"))          # no atomic-write litter


def test_resume_of_completed_fit_is_a_no_op_reload(setup, tmp_path):
    """resume=True over a fully committed checkpoint dir reloads the
    trees without touching the source (zero reads)."""
    _, params, src, ref = setup
    ck = str(tmp_path / "ck")
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=ck)
    counter = FaultyRowSource(src)               # no faults: counts reads
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        counter, checkpoint_dir=ck, resume=True)
    assert counter.reads == 0
    _assert_forests_identical(ref, fr, "reload")


# ---------------------------------------------------------------------------
# Retry: transient faults are invisible, persistent ones escalate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", [
    {0: 1},                      # first read hiccups once
    {0: 3, 1: 3, 2: 3},          # every early read at the retry limit
    {7: 2, 13: 1, 19: 3},        # scattered mid-fit
])
def test_transient_faults_never_change_forest(setup, schedule, caplog):
    _, params, src, ref = setup
    flaky = FaultyRowSource(src, transient=dict(schedule))
    with caplog.at_level(logging.WARNING, logger="repro.core.stream"):
        ff = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
            flaky)
    _assert_forests_identical(ref, ff, f"transient{schedule}")
    expected_failures = sum(schedule.values())
    assert flaky.attempts == flaky.reads + expected_failures
    warnings = [r for r in caplog.records
                if "transient stream read failure" in r.message]
    assert len(warnings) == expected_failures


def test_persistent_fault_flushes_checkpoint_then_escalates(setup, tmp_path):
    """A read that fails every retry raises StreamReadError — but only
    AFTER the last completed level's snapshot hit the disk, so the
    resume replays just the interrupted level and lands bit-identical."""
    _, params, src, ref = setup
    ck = str(tmp_path / "ck")
    dead = FaultyRowSource(src, persistent={17})
    with pytest.raises(StreamReadError, match="after 4 attempts"):
        RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
            dead, checkpoint_dir=ck, checkpoint_every=3)
    # checkpoint_every=3 means the level snapshot would normally still be
    # pending — the escalation path must have flushed it
    assert os.path.exists(os.path.join(ck, "snap_0-2.npz"))
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=ck, resume=True)
    _assert_forests_identical(ref, fr, "resume-after-dead-read")


def test_resume_skips_completed_tree_batches(tmp_path):
    """Mid-forest granularity: a crash in the second tree batch leaves
    the first committed; the resume retrains ONLY the second."""
    ds = make_tabular("xor", n=400, num_informative=3, num_useless=1,
                      seed=11)
    params = tree_lib.TreeParams(max_depth=4, split_mode="hist",
                                 num_bins=16)
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=101)
    ref = RandomForest(params=params, num_trees=4, seed=5,
                       tree_batch=2).fit_streamed(src)
    # reads one clean 2-tree batch takes, to aim the fault at batch 2
    probe = FaultyRowSource(src)
    RandomForest(params=params, num_trees=2, seed=5,
                 tree_batch=2).fit_streamed(probe)
    per_batch = probe.reads
    ck = str(tmp_path / "ck")
    # land the fault in batch 2 AFTER its first level completed, so the
    # resume provably restarts from the snapshot (fewer reads than a
    # full batch) instead of from scratch
    chunks_per_level = -(-400 // 101)
    dead = FaultyRowSource(src, persistent={per_batch + chunks_per_level + 1})
    with pytest.raises(StreamReadError):
        RandomForest(params=params, num_trees=4, seed=5,
                     tree_batch=2).fit_streamed(dead, checkpoint_dir=ck)
    with open(os.path.join(ck, "manifest.json")) as f:
        batches = json.load(f)["batches"]
    assert "0-1" in batches and "2-3" not in batches
    counter = FaultyRowSource(src)
    fr = RandomForest(params=params, num_trees=4, seed=5,
                      tree_batch=2).fit_streamed(counter, checkpoint_dir=ck,
                                                 resume=True)
    assert 0 < counter.reads < per_batch     # batch 1 skipped, 2 partial
    _assert_forests_identical(ref, fr, "mid-forest-resume")


# ---------------------------------------------------------------------------
# Fingerprints: resuming against the wrong state is a typed error
# ---------------------------------------------------------------------------

def test_resume_fingerprint_mismatch(setup, tmp_path):
    ds, params, src, _ = setup
    ck = str(tmp_path / "ck")
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=ck)
    # wrong seed
    with pytest.raises(checkpoint.CheckpointMismatchError, match="seed"):
        RandomForest(params=params, num_trees=3, seed=8).fit_streamed(
            src, checkpoint_dir=ck, resume=True)
    # wrong params
    deeper = tree_lib.TreeParams(max_depth=7, split_mode="hist",
                                 num_bins=16, prune_closed_frac=0.3)
    with pytest.raises(checkpoint.CheckpointMismatchError, match="params"):
        RandomForest(params=deeper, num_trees=3, seed=7).fit_streamed(
            src, checkpoint_dir=ck, resume=True)
    # wrong source (different data -> different edges hash)
    other = make_tabular("xor", n=600, num_informative=4, num_useless=2,
                         seed=4)
    osrc = ArrayRowSource.from_dataset(other, params.num_bins,
                                       chunk_size=149)
    with pytest.raises(checkpoint.CheckpointMismatchError, match="source"):
        RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
            osrc, checkpoint_dir=ck, resume=True)
    # resume=False discards the old state instead of raising
    f2 = RandomForest(params=params, num_trees=3, seed=8).fit_streamed(
        src, checkpoint_dir=ck)
    assert len(f2.trees) == 3


def test_resume_true_on_empty_dir_trains_fresh(setup, tmp_path):
    """Crash-loop supervisors pass resume=True unconditionally; the
    first run (nothing on disk yet) must simply train."""
    _, params, src, ref = setup
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=str(tmp_path / "fresh"), resume=True)
    _assert_forests_identical(ref, fr, "fresh-resume")


# ---------------------------------------------------------------------------
# Atomic writes: the replace window cannot corrupt anything
# ---------------------------------------------------------------------------

def test_atomic_replace_failure_preserves_target(tmp_path):
    path = str(tmp_path / "f.txt")
    atomicio.atomic_replace(path, lambda t: open(t, "w").write("v1"))
    assert open(path).read() == "v1"

    def exploding_hook(final, tmp):
        raise RuntimeError("crash in the replace window")
    atomicio.PRE_REPLACE_HOOK[0] = exploding_hook
    with pytest.raises(RuntimeError, match="replace window"):
        atomicio.atomic_replace(path, lambda t: open(t, "w").write("v2"))
    assert open(path).read() == "v1"            # old file intact
    assert os.listdir(tmp_path) == ["f.txt"]    # tmp cleaned up


def test_packed_forest_save_is_atomic(setup, tmp_path):
    """A failure between the tmp write and the replace leaves the
    previous complete model loadable (no truncated .npz)."""
    ds, params, _, ref = setup
    path = str(tmp_path / "model.npz")
    ref._packed_forest().save(path)
    before = PackedForest.load(path)

    other = RandomForest(params=params, num_trees=2, seed=1).fit(ds)
    atomicio.PRE_REPLACE_HOOK[0] = lambda final, tmp: (_ for _ in ()).throw(
        OSError("killed mid-save"))
    with pytest.raises(OSError, match="mid-save"):
        other._packed_forest().save(path)
    faults.disarm()
    after = PackedForest.load(path)             # still the OLD model
    assert after.num_trees == before.num_trees == 3
    np.testing.assert_array_equal(np.asarray(after.feature),
                                  np.asarray(before.feature))


# ---------------------------------------------------------------------------
# Memmap cache integrity (sidecar metadata)
# ---------------------------------------------------------------------------

def _build_memmap(tmp_path, n=200, m=3, num_bins=16):
    rng = np.random.default_rng(0)
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = (num[:, 0] > 0).astype(np.int32)
    path = str(tmp_path / "bins.npy")
    src = MemmapRowSource.from_numpy(num, y, num_bins=num_bins, path=path)
    return src, path, num, y


def test_memmap_build_writes_sidecar_and_opens_clean(tmp_path):
    src, path, _, _ = _build_memmap(tmp_path)
    with open(MemmapRowSource.meta_path(path)) as f:
        meta = json.load(f)
    assert meta["n"] == 200 and meta["m_num"] == 3
    assert meta["num_bins"] == 16 and meta["dtype"] == "uint8"
    assert src.bins_block(0, 7).shape == (3, 7)  # verification passes


def test_memmap_truncated_cache_raises(tmp_path):
    src, path, num, y = _build_memmap(tmp_path)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 64)
    fresh = MemmapRowSource(path, src.edges, y, num_classes=2)
    with pytest.raises(CacheIntegrityError):
        fresh.bins_block(0, 7)


def test_memmap_sidecar_mismatch_raises(tmp_path):
    src, path, num, y = _build_memmap(tmp_path)
    mp = MemmapRowSource.meta_path(path)
    for field, value in (("n", 999), ("edges_sha256", "0" * 64),
                         ("dtype", "uint16")):
        with open(mp) as f:
            meta = json.load(f)
        meta[field] = value
        with open(mp, "w") as f:
            json.dump(meta, f)
        fresh = MemmapRowSource(path, src.edges, y, num_classes=2)
        with pytest.raises(CacheIntegrityError, match="sidecar"):
            fresh.bins_block(0, 7)
        # restore for the next field
        meta[field] = fresh._expected_meta()[field]
        with open(mp, "w") as f:
            json.dump(meta, f)


def test_memmap_legacy_cache_without_sidecar_still_opens(tmp_path, caplog):
    src, path, num, y = _build_memmap(tmp_path)
    os.unlink(MemmapRowSource.meta_path(path))
    fresh = MemmapRowSource(path, src.edges, y, num_classes=2)
    with caplog.at_level(logging.WARNING, logger="repro.core.stream"):
        blk = fresh.bins_block(0, 7)
    assert blk.shape == (3, 7)
    assert any("no sidecar" in r.message for r in caplog.records)


# ---------------------------------------------------------------------------
# Hypothesis: random fault schedules never change the forest
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=12, deadline=None)
    @given(st.dictionaries(st.integers(0, 40), st.integers(1, 3),
                           max_size=6))
    def test_property_transient_schedules_are_invisible(schedule):
        ds = make_tabular("xor", n=96, num_informative=3, num_useless=1,
                          seed=5)
        params = tree_lib.TreeParams(max_depth=3, split_mode="hist",
                                     num_bins=8)
        src = ArrayRowSource.from_dataset(ds, params.num_bins,
                                          chunk_size=17)
        ref = RandomForest(params=params, num_trees=1, seed=2).fit_streamed(
            src)
        flaky = FaultyRowSource(src, transient=schedule)
        got = RandomForest(params=params, num_trees=1, seed=2).fit_streamed(
            flaky)
        _assert_forests_identical(ref, got, f"prop{schedule}")


# ---------------------------------------------------------------------------
# SIGKILL -> resume -> parity (subprocess; `-m faults`)
# ---------------------------------------------------------------------------

_SUB_SETUP = """
    import numpy as np
    from repro.core import tree as tree_lib
    from repro.core.dataset import ArrayRowSource, MemmapRowSource
    from repro.core.forest import RandomForest
    from repro.data.synthetic import make_tabular
    from repro.testing import faults
    from repro.testing.faults import FaultyRowSource

    ds = make_tabular('xor', n=600, num_informative=4, num_useless=2,
                      seed=3)
    params = tree_lib.TreeParams(max_depth=5, split_mode='hist',
                                 num_bins=16, prune_closed_frac=0.3)
"""


def _run_expect_sigkill(code: str) -> None:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == -signal.SIGKILL, (
        f"expected SIGKILL, got rc={out.returncode}\n{out.stderr[-3000:]}")


def _memmap_source(setup, tmp_path):
    ds, params, _, _ = setup
    return MemmapRowSource.from_numpy(
        np.asarray(ds.num), np.asarray(ds.labels),
        num_bins=params.num_bins, path=str(tmp_path / "bins.npy"),
        chunk_size=149, num_classes=ds.num_classes)


@pytest.mark.faults
@pytest.mark.parametrize("backend", ["array", "memmap"])
def test_sigkill_mid_fit_resume_is_bit_identical(setup, tmp_path, backend):
    """Kill the training process outright (SIGKILL at a scheduled chunk
    read — no cleanup runs), resume from the checkpoint dir in THIS
    process, and get the reference forest node for node."""
    _, params, src, ref = setup
    ck = str(tmp_path / "ck")
    cache = str(tmp_path / "bins.npy")
    _run_expect_sigkill(_SUB_SETUP + f"""
    if {backend!r} == 'array':
        src = ArrayRowSource.from_dataset(ds, params.num_bins,
                                          chunk_size=149)
    else:
        src = MemmapRowSource.from_numpy(
            np.asarray(ds.num), np.asarray(ds.labels),
            num_bins=params.num_bins, path={cache!r},
            chunk_size=149, num_classes=ds.num_classes)
    doomed = FaultyRowSource(src, kill_after_reads=14)
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        doomed, checkpoint_dir={ck!r})
    raise SystemExit('unreachable: the kill must fire mid-fit')
    """)
    assert os.path.exists(os.path.join(ck, "snap_0-2.npz"))
    resume_src = (src if backend == "array"
                  else _memmap_source(setup, tmp_path))
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        resume_src, checkpoint_dir=ck, resume=True)
    _assert_forests_identical(ref, fr, f"sigkill-{backend}")


@pytest.mark.faults
def test_sigkill_mid_checkpoint_replace_keeps_previous_snapshot(
        setup, tmp_path):
    """Kill INSIDE the snapshot's atomic-write window (tmp flushed,
    replace pending): the previous snapshot must survive intact and the
    resume from it must still be bit-identical."""
    _, params, src, ref = setup
    ck = str(tmp_path / "ck")
    _run_expect_sigkill(_SUB_SETUP + f"""
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=149)
    faults.arm_kill_mid_replace(nth=2, match='snap_')
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir={ck!r})
    raise SystemExit('unreachable: the kill must fire mid-write')
    """)
    # the first snapshot survived the second one's death mid-replace
    snap = checkpoint.StreamCheckpointer(ck).load_snapshot([0, 1, 2])
    assert snap is not None and int(snap["next_depth"]) == 1
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=ck, resume=True)
    _assert_forests_identical(ref, fr, "sigkill-mid-replace")


@pytest.mark.faults
def test_sigkill_after_chosen_snapshot_resumes(setup, tmp_path):
    """Kill-at-level: die right after the 3rd snapshot commits; the
    resume starts at depth 3 and replays the rest bit-identically."""
    _, params, src, ref = setup
    ck = str(tmp_path / "ck")
    _run_expect_sigkill(_SUB_SETUP + f"""
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=149)
    faults.arm_kill_after_snapshots(nth=3)
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir={ck!r})
    raise SystemExit('unreachable: the kill must fire at level 3')
    """)
    snap = checkpoint.StreamCheckpointer(ck).load_snapshot([0, 1, 2])
    assert snap is not None and int(snap["next_depth"]) == 3
    fr = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
        src, checkpoint_dir=ck, resume=True)
    _assert_forests_identical(ref, fr, "sigkill-at-level")


@pytest.mark.faults
def test_sigkill_mid_model_save_keeps_previous_model(setup, tmp_path):
    """`PackedForest.save` atomicity under a real SIGKILL: the file on
    disk after a mid-replace death is the previous COMPLETE model."""
    _, params, src, ref = setup
    path = str(tmp_path / "model.npz")
    ref._packed_forest().save(path)
    _run_expect_sigkill(_SUB_SETUP + f"""
    from repro.core.forest import PackedForest
    other = RandomForest(params=params, num_trees=2, seed=1).fit(ds)
    faults.arm_kill_mid_replace(match='model.npz')
    other._packed_forest().save({path!r})
    raise SystemExit('unreachable: the kill must fire mid-save')
    """)
    loaded = PackedForest.load(path)
    assert loaded.num_trees == 3                 # still the old forest
    np.testing.assert_array_equal(
        np.asarray(loaded.feature),
        np.asarray(ref._packed_forest().feature))
