"""`ForestServer` graceful degradation (DESIGN.md §9).

Malformed requests — wrong feature count, non-finite numeric rows,
categorical ids outside the declared arity, wrong dtypes/shapes — must
raise the typed `InvalidRequest` BEFORE the jitted descent and leave
the server fully serving: every test fires a bad request, catches the
error, and asserts the next good request still answers correctly.
"""
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.serve.engine import ForestServer, InvalidRequest


@pytest.fixture(scope="module")
def servers(tmp_path_factory):
    """A numeric-only server and a mixed numeric+categorical one."""
    tmp = tmp_path_factory.mktemp("srv")
    rng = np.random.default_rng(0)
    n = 400
    num = rng.normal(size=(n, 3)).astype(np.float32)
    cat = rng.integers(0, 4, size=(n, 2)).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 0] == 1)).astype(np.int32)
    params = tree_lib.TreeParams(max_depth=4)

    ds_num = from_numpy(num, None, y)
    f_num = RandomForest(params=params, num_trees=3, seed=0).fit(ds_num)
    p_num = str(tmp / "num.npz")
    f_num._packed_forest().save(p_num)

    ds_mix = from_numpy(num, cat, y, arities=(4, 4))
    f_mix = RandomForest(params=params, num_trees=3, seed=0).fit(ds_mix)
    p_mix = str(tmp / "mix.npz")
    f_mix._packed_forest().save(p_mix)

    srv_num = ForestServer.load(p_num)
    srv_mix = ForestServer.load(p_mix, m_cat=2, arities=(4, 4))
    return srv_num, srv_mix


def _good_num():
    return np.zeros((2, 3), np.float32)


def _good_cat():
    return np.zeros((2, 2), np.int32)


def _assert_still_serving(srv, cat=None):
    """The recovery half of every test: a well-formed request after the
    rejected one gets a normal answer."""
    out = np.asarray(srv.predict(_good_num(), cat))
    assert out.shape == (2, 2)
    np.testing.assert_allclose(out.sum(-1), 1.0, rtol=1e-5)


def test_wrong_feature_count_rejected(servers):
    srv, _ = servers
    with pytest.raises(InvalidRequest, match=r"\(B, 3\)"):
        srv.predict(np.zeros((2, 5), np.float32))
    with pytest.raises(InvalidRequest, match=r"\(B, 3\)"):
        srv.predict(np.zeros((3,), np.float32))      # missing batch axis
    _assert_still_serving(srv)


@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_non_finite_rows_rejected(servers, bad):
    srv, _ = servers
    x = _good_num()
    x[1, 2] = bad
    with pytest.raises(InvalidRequest, match="row 1, column 2"):
        srv.predict(x)
    _assert_still_serving(srv)


def test_categorical_out_of_arity_rejected(servers):
    _, srv = servers
    cat = _good_cat()
    cat[0, 1] = 4                                    # arity 4: ids 0..3
    with pytest.raises(InvalidRequest, match="column 1 has id 4"):
        srv.predict(_good_num(), cat)
    cat = _good_cat()
    cat[1, 0] = -1
    with pytest.raises(InvalidRequest, match=">= 0"):
        srv.predict(_good_num(), cat)
    _assert_still_serving(srv, _good_cat())


def test_categorical_shape_and_dtype_rejected(servers):
    _, srv = servers
    with pytest.raises(InvalidRequest, match=r"\(B, 2\)"):
        srv.predict(_good_num(), np.zeros((2, 3), np.int32))
    with pytest.raises(InvalidRequest, match="batch"):
        srv.predict(_good_num(), np.zeros((4, 2), np.int32))
    with pytest.raises(InvalidRequest, match="integer"):
        srv.predict(_good_num(), np.zeros((2, 2), np.float32))
    _assert_still_serving(srv, _good_cat())


def test_missing_categorical_row_rejected(servers):
    _, srv = servers
    with pytest.raises(InvalidRequest, match="m_cat=2"):
        srv.predict(_good_num())
    _assert_still_serving(srv, _good_cat())


def test_arities_length_validated_at_load(servers, tmp_path):
    _, srv = servers
    # reuse the mixed model file through the server's own packed forest
    path = str(tmp_path / "again.npz")
    srv.packed.save(path)
    with pytest.raises(ValueError, match="one arity per"):
        ForestServer.load(path, m_cat=2, arities=(4,))


def test_invalid_request_is_a_value_error(servers):
    """Back-compat: callers that caught ValueError keep working."""
    srv, _ = servers
    with pytest.raises(ValueError):
        srv.predict(np.zeros((2, 5), np.float32))
