"""Tree builder (Alg. 2) + RandomForest + GBT behaviour tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.core.gbt import GBTModel, GBTParams
from repro.data.synthetic import make_tabular, train_test_split


@pytest.fixture(scope="module")
def small_ds():
    rng = np.random.default_rng(3)
    n = 1200
    num = rng.normal(size=(n, 4)).astype(np.float32)
    cat = rng.integers(0, 5, size=(n, 2)).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 0] >= 3)).astype(np.int32)
    return from_numpy(num, cat, y)


def test_backends_build_identical_trees(small_ds):
    trees = {}
    for backend in ("scan", "segment", "kernel"):
        rf = RandomForest(tree_lib.TreeParams(max_depth=4, backend=backend),
                          num_trees=2, seed=5).fit(small_ds)
        trees[backend] = rf.trees
    for backend in ("segment", "kernel"):
        for ta, tb in zip(trees["scan"], trees[backend]):
            assert ta.num_nodes == tb.num_nodes
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_allclose(ta.threshold, tb.threshold, atol=1e-4)
            np.testing.assert_array_equal(ta.children, tb.children)


def test_forest_learns(small_ds):
    rf = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=2),
                      num_trees=5, seed=0).fit(small_ds)
    acc = float((np.asarray(rf.predict(small_ds.num, small_ds.cat))
                 == np.asarray(small_ds.labels)).mean())
    assert acc > 0.8
    assert rf.auc(small_ds) > 0.9
    oob = rf.oob_score(small_ds)
    assert oob > 0.7


def test_min_records_and_depth_respected(small_ds):
    p = tree_lib.TreeParams(max_depth=3, min_records=50)
    rf = RandomForest(p, num_trees=1, seed=0).fit(small_ds)
    tr = rf.trees[0]
    assert tr.max_depth_reached <= 3
    leaves = tr.feature < 0
    # every SPLIT must leave >= min_records on both sides
    internal = ~leaves
    for node in np.where(internal)[0]:
        l, r = tr.children[node]
        assert tr.n_node[l] >= p.min_records - 1e-6
        assert tr.n_node[r] >= p.min_records - 1e-6


def test_deterministic_given_seed(small_ds):
    p = tree_lib.TreeParams(max_depth=4)
    a = RandomForest(p, num_trees=2, seed=9).fit(small_ds)
    b = RandomForest(p, num_trees=2, seed=9).fit(small_ds)
    for ta, tb in zip(a.trees, b.trees):
        np.testing.assert_array_equal(ta.feature, tb.feature)
        np.testing.assert_allclose(ta.threshold, tb.threshold)


def test_usb_variant_trains(small_ds):
    rf = RandomForest(tree_lib.TreeParams(max_depth=4, usb=True),
                      num_trees=2, seed=0).fit(small_ds)
    acc = float((np.asarray(rf.predict(small_ds.num, small_ds.cat))
                 == np.asarray(small_ds.labels)).mean())
    assert acc > 0.8


def test_feature_importance_finds_informative():
    ds = make_tabular("linear", 2000, num_informative=3, num_useless=5, seed=1)
    rf = RandomForest(tree_lib.TreeParams(max_depth=6), num_trees=5,
                      seed=0).fit(ds)
    imp = rf.feature_importances()
    # the 3 informative features should dominate the 5 useless ones
    assert imp[:3].sum() > 0.7


def test_pure_categorical_dataset():
    rng = np.random.default_rng(0)
    n = 800
    cat = rng.integers(0, 6, size=(n, 3)).astype(np.int32)
    y = ((cat[:, 0] % 2) ^ (cat[:, 1] >= 3)).astype(np.int32)
    ds = from_numpy(None, cat, y)
    rf = RandomForest(tree_lib.TreeParams(max_depth=6), num_trees=3,
                      seed=0).fit(ds)
    acc = float((np.asarray(rf.predict(ds.num, ds.cat)) == y).mean())
    assert acc > 0.9


def test_level_stats_match_paper_costs(small_ds):
    """The recorded per-level counters must follow Table 1's DRF row:
    one bit per (in-bag, open-leaf) sample per level; class list bits
    n·⌈log2(ℓ+1)⌉."""
    rf = RandomForest(tree_lib.TreeParams(max_depth=5), num_trees=1,
                      seed=0).fit(small_ds, collect_stats=True)
    stats = rf.level_stats[0]
    assert len(stats) >= 2
    n = small_ds.n
    for s in stats:
        assert s.network_bits_bitmap <= 3 * n       # ~n (poisson weights)
        bits = int(np.ceil(np.log2(s.open_leaves + 1)))
        assert s.class_list_bits == n * bits


def test_gbt_regression_and_logistic():
    rng = np.random.default_rng(1)
    n = 900
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2).astype(np.float32)
    ds = from_numpy(num, None, y, task="regression")
    gbt = GBTModel(GBTParams(num_rounds=12, max_depth=3,
                             learning_rate=0.3)).fit(ds)
    rmse = float(np.sqrt(((gbt.predict(ds.num, ds.cat) - y) ** 2).mean()))
    assert rmse < 0.5 * y.std()

    yb = (num[:, 0] + num[:, 2] > 0).astype(np.int32)
    ds2 = from_numpy(num, None, yb)
    g2 = GBTModel(GBTParams(num_rounds=12, max_depth=3, learning_rate=0.3,
                            loss="logistic")).fit(ds2)
    acc = float((g2.predict(ds2.num, ds2.cat) == yb).mean())
    assert acc > 0.9


def test_generalization_on_holdout():
    ds = make_tabular("majority", 3000, num_informative=5, num_useless=3,
                      seed=2)
    tr, te = train_test_split(ds)
    rf = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=2),
                      num_trees=5, seed=0).fit(tr)
    acc = float((np.asarray(rf.predict(te.num, te.cat))
                 == np.asarray(te.labels)).mean())
    assert acc > 0.8


def test_sprint_pruning_switch_exact():
    """Paper §3: the Sprint-style record-pruning mode must not change the
    model (it only compacts rows already in closed leaves)."""
    rng = np.random.default_rng(0)
    n = 2000
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (num[:, 0] > 1.2).astype(np.int32)   # skewed: leaves close early
    ds = from_numpy(num, None, y)
    a = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=50),
                     num_trees=2, seed=3).fit(ds)
    b = RandomForest(tree_lib.TreeParams(max_depth=8, min_records=50,
                                         prune_closed_frac=0.3),
                     num_trees=2, seed=3).fit(ds)
    for ta, tb in zip(a.trees, b.trees):
        assert ta.num_nodes == tb.num_nodes
        np.testing.assert_array_equal(ta.feature, tb.feature)
        np.testing.assert_allclose(ta.threshold, tb.threshold, atol=1e-4)


def test_distributed_importance_decomposition():
    """Paper goal (5): feature importance decomposes over splitters —
    per-column-range partials sum to the global MDI."""
    from repro.core import importance
    ds = make_tabular("linear", 1500, num_informative=3, num_useless=3,
                      seed=6)
    rf = RandomForest(tree_lib.TreeParams(max_depth=5), num_trees=3,
                      seed=0).fit(ds)
    m = ds.m
    total = np.zeros(m)
    for lo in range(0, m, 2):                      # 3 "splitters", 2 cols each
        total += importance.mdi_partial(rf.trees, m, lo, lo + 2)
    ref = importance.mdi_importance(rf.trees, m)
    np.testing.assert_allclose(total / max(total.sum(), 1e-12), ref,
                               atol=1e-6)


def test_permutation_importance_agrees_with_mdi():
    from repro.core import importance
    ds = make_tabular("linear", 2000, num_informative=2, num_useless=4,
                      seed=7)
    rf = RandomForest(tree_lib.TreeParams(max_depth=6), num_trees=5,
                      seed=0).fit(ds)
    perm = importance.permutation_importance(rf, ds, seed=0)
    # informative features must outrank the useless ones in both measures
    assert perm[:2].sum() > perm[2:].sum()
