"""Streaming-parity suite: out-of-core training is BIT-EXACT.

`RandomForest.fit_streamed(source)` must produce node-for-node identical
trees to `fit(ds)` in hist mode — same features, same decoded float
thresholds, same child numbering, same leaf values and counts — for every
chunk size (including a single padded chunk larger than n and chunk=1),
for batched and per-tree building, with Sprint pruning on, and from a
disk-backed memory-mapped bin cache.  The chain that makes this possible
(DESIGN.md §8): streaming quantile edges bit-equal to the in-memory
recipe -> identical bin ids -> order-independent integer table
accumulation -> identical scoring arithmetic -> identical host decisions.

Also here: the chunked-accumulation property test (random chunk
boundaries vs one-pass tables, exact equality), the trace-count guard
(one compiled chunk program per level shape — no retrace per chunk), and
the 2x4-mesh sharded streaming parity subprocess test.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import presort, splits, tree as tree_lib
from repro.core.dataset import (ArrayRowSource, MemmapRowSource, RowSource,
                                from_numpy)
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular
from repro.kernels import ops as kops

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dev dependency
    HAVE_HYPOTHESIS = False

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FIELDS = ("feature", "children", "threshold", "is_cat", "cat_mask",
          "value", "n_node", "gain", "depth")


def _assert_identical(ta, tb, ctx=""):
    """Node-for-node bitwise equality of two flat trees."""
    assert ta.num_nodes == tb.num_nodes, f"{ctx}: node count"
    for f in FIELDS:
        np.testing.assert_array_equal(
            getattr(ta, f), getattr(tb, f), err_msg=f"{ctx}: {f}")


def _assert_forests_identical(fa, fb, ctx=""):
    assert len(fa.trees) == len(fb.trees), ctx
    for t, (ta, tb) in enumerate(zip(fa.trees, fb.trees)):
        _assert_identical(ta, tb, f"{ctx}/tree{t}")


@pytest.fixture(scope="module")
def hist_setup():
    """A reference in-memory hist fit plus its streamable source."""
    ds = make_tabular("xor", n=900, num_informative=4, num_useless=2,
                      seed=3)
    params = tree_lib.TreeParams(max_depth=6, split_mode="hist",
                                 num_bins=32)
    ref = RandomForest(params=params, num_trees=3, seed=7).fit(ds)
    return ds, params, ref


# ---------------------------------------------------------------------------
# Core parity: chunk sizes, batching, pruning, disk backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [900, 300, 977, 173])
def test_streamed_fit_bit_identical_across_chunk_sizes(hist_setup, chunk):
    """chunk == n (one block), n/3 (even), 977 > n (single padded block),
    173 (uneven tail) — all bit-identical to the in-memory fit."""
    ds, params, ref = hist_setup
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=chunk)
    fs = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(src)
    _assert_forests_identical(ref, fs, f"chunk{chunk}")


def test_streamed_fit_chunk_one_per_tree():
    """chunk_size=1 (every row its own block) through the per-tree builder
    (tree_batch=1) — the degenerate extreme of the accumulation loop."""
    ds = make_tabular("xor", n=96, num_informative=3, num_useless=1, seed=5)
    params = tree_lib.TreeParams(max_depth=4, split_mode="hist",
                                 num_bins=16)
    ref = RandomForest(params=params, num_trees=2, seed=2,
                       tree_batch=1).fit(ds)
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=1)
    fs = RandomForest(params=params, num_trees=2, seed=2,
                      tree_batch=1).fit_streamed(src)
    _assert_forests_identical(ref, fs, "chunk1")


def test_streamed_fit_with_pruning():
    """Sprint record pruning compacts the HOST row state mid-training; the
    trees must not notice."""
    ds = make_tabular("majority", n=600, num_informative=4, num_useless=2,
                      seed=1)
    params = tree_lib.TreeParams(max_depth=5, split_mode="hist",
                                 num_bins=16, prune_closed_frac=0.25)
    ref = RandomForest(params=params, num_trees=3, seed=9).fit(ds)
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=97)
    fs = RandomForest(params=params, num_trees=3, seed=9).fit_streamed(src)
    _assert_forests_identical(ref, fs, "pruned")


def test_memmap_source_parity(hist_setup, tmp_path):
    """Disk-backed bin cache (built by the streaming quantizer, no full
    float column ever materialized) trains the same trees, and its edges
    are bit-equal to the in-memory quantization."""
    ds, params, ref = hist_setup
    mem = ArrayRowSource.from_dataset(ds, params.num_bins)
    src = MemmapRowSource.from_numpy(
        np.asarray(ds.num), np.asarray(ds.labels),
        num_bins=params.num_bins, path=str(tmp_path / "bins.npy"),
        chunk_size=97, num_classes=ds.num_classes)
    np.testing.assert_array_equal(src.edges, mem.edges)
    fs = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(src)
    _assert_forests_identical(ref, fs, "memmap")
    # predictions follow from node-identity, but check the packed path too
    xq = np.asarray(ds.num[:64])
    xc = np.zeros((64, 0), np.int32)
    np.testing.assert_array_equal(np.asarray(ref.predict(xq, xc)),
                                  np.asarray(fs.predict(xq, xc)))


def test_streaming_quantile_edges_bit_equal():
    """The 3-pass radix-select quantizer == sort-the-column quantization,
    bit for bit, across distributions and bucket budgets."""
    cases = [(1000, 3, 16, "normal"), (977, 2, 255, "uniform"),
             (64, 4, 64, "ties"), (5000, 1, 7, "negskew")]
    for n, m, B, kind in cases:
        rng = np.random.default_rng(hash(kind) % 2**31)
        if kind == "normal":
            num = rng.normal(size=(n, m))
        elif kind == "uniform":
            num = rng.uniform(-5, 5, size=(n, m))
        elif kind == "ties":
            num = np.round(rng.normal(size=(n, m)) * 2) / 2
        else:
            num = -np.abs(rng.normal(size=(n, m))) ** 3
        num = num.astype(np.float32)

        def chunks(num=num):
            for lo in range(0, n, 173):
                yield num[lo:lo + 173]

        got = presort.streaming_quantile_edges(chunks, n, m, B)
        si = presort.presort_columns(jnp.asarray(num))
        sv = presort.gather_sorted(jnp.asarray(num), si)
        want = np.asarray(presort.quantize_edges(sv, B))
        np.testing.assert_array_equal(got, want, err_msg=f"{kind}/B{B}")
        np.testing.assert_array_equal(
            presort.bin_block(num, got),
            np.asarray(presort.bin_columns(jnp.asarray(num),
                                           jnp.asarray(want))),
            err_msg=f"{kind}/B{B}/bins")


# ---------------------------------------------------------------------------
# Error paths + from_numpy laziness
# ---------------------------------------------------------------------------

def test_stream_error_paths(hist_setup):
    ds, params, _ = hist_setup
    src = ArrayRowSource.from_dataset(ds, params.num_bins)
    exact = tree_lib.TreeParams(max_depth=3, split_mode="exact")
    with pytest.raises(ValueError, match="only hist streams"):
        RandomForest(params=exact, num_trees=1).fit_streamed(src)
    with pytest.raises(TypeError, match="fit_streamed"):
        RandomForest(params=params, num_trees=1).fit(src)
    with pytest.raises(TypeError, match="RowSource"):
        RandomForest(params=params, num_trees=1).fit_streamed(ds)
    bad = tree_lib.TreeParams(max_depth=3, split_mode="hist", num_bins=64)
    with pytest.raises(ValueError, match="num_bins"):
        RandomForest(params=bad, num_trees=1).fit_streamed(src)


def test_from_numpy_stays_host_resident():
    """`from_numpy` must NOT device-put columns eagerly — a memmap input
    would fault the whole file.  The fit entry points device-put later."""
    num = np.random.default_rng(0).normal(size=(50, 3)).astype(np.float32)
    y = (num[:, 0] > 0).astype(np.int32)
    ds = from_numpy(num, None, y)
    assert isinstance(ds.num, np.ndarray)
    assert isinstance(ds.labels, np.ndarray)
    # ...and training still works from the lazy dataset
    params = tree_lib.TreeParams(max_depth=2, split_mode="hist", num_bins=8)
    f = RandomForest(params=params, num_trees=1, seed=0).fit(ds)
    assert f.trees[0].num_nodes >= 1


# ---------------------------------------------------------------------------
# Trace counts: one compiled program per depth, not per chunk
# ---------------------------------------------------------------------------

def test_streaming_one_program_per_level_shape(hist_setup):
    """Chunk-program compilations are bounded by the number of distinct
    (level shape) configurations — O(log L), never O(chunks) — and a warm
    refit with identical shapes adds chunk CALLS but ZERO new traces."""
    from repro.core.level import plan as plan_mod
    ds, params, _ = hist_setup
    src = ArrayRowSource.from_dataset(ds, params.num_bins, chunk_size=123)

    c0 = plan_mod._STREAM_CHUNK_CALLS[0]
    t0 = plan_mod._STREAM_CHUNK_TRACES[0]
    s0 = plan_mod._STREAM_SCORE_TRACES[0]
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(src)
    calls = plan_mod._STREAM_CHUNK_CALLS[0] - c0
    traces = plan_mod._STREAM_CHUNK_TRACES[0] - t0
    straces = plan_mod._STREAM_SCORE_TRACES[0] - s0
    chunks_per_level = -(-900 // 123)
    assert calls >= chunks_per_level          # it really streamed
    # statics are (plan, Lp, Lpp, root, need_tables): at most one trace per
    # (depth-padded leaf count transition) + the root level — far fewer
    # than the number of chunk dispatches
    assert traces <= params.max_depth + 2, (traces, calls)
    assert traces < calls
    assert straces <= params.max_depth + 1

    # warm refit: same shapes -> zero new compilations, calls still grow
    t1 = plan_mod._STREAM_CHUNK_TRACES[0]
    s1 = plan_mod._STREAM_SCORE_TRACES[0]
    c1 = plan_mod._STREAM_CHUNK_CALLS[0]
    RandomForest(params=params, num_trees=3, seed=7).fit_streamed(src)
    assert plan_mod._STREAM_CHUNK_TRACES[0] == t1
    assert plan_mod._STREAM_SCORE_TRACES[0] == s1
    assert plan_mod._STREAM_CHUNK_CALLS[0] > c1


# ---------------------------------------------------------------------------
# Chunked-accumulation property: random boundaries == one pass, exactly
# ---------------------------------------------------------------------------

def _acc_case(seed, n=257, m=3, L=4, B=16, C=3):
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, B, size=(m, n)).astype(np.uint8)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    return bins, leaf, w, y


def _check_chunked_accumulation(seed, cuts):
    """Tables accumulated over arbitrary chunk boundaries (uneven, empty,
    single-row) must equal the single-pass tables EXACTLY, for both the
    jnp segment-sum path and the Pallas kernel path."""
    n, m, L, B, C = 257, 3, 4, 16, 3
    bins, leaf, w, y = _acc_case(seed, n, m, L, B, C)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), C,
                             "classification")
    one_pass = np.asarray(splits.feature_count_tables(
        jnp.asarray(bins), jnp.asarray(leaf), jnp.asarray(w), stats, L, B))
    bounds = [0] + sorted(min(c, n) for c in cuts) + [n]
    acc = np.zeros_like(one_pass)
    kacc = np.zeros_like(one_pass)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi == lo:                      # empty chunk: must be a no-op
            continue
        sl = slice(lo, hi)
        acc += np.asarray(splits.feature_count_tables(
            jnp.asarray(bins[:, sl]), jnp.asarray(leaf[sl]),
            jnp.asarray(w[sl]), stats[lo:hi], L, B))
        kacc += np.asarray(kops.feature_tables(
            jnp.asarray(bins[:, sl]), jnp.asarray(leaf[sl]),
            jnp.asarray(w[sl]), jnp.asarray(y[sl]), B=B, W=L + 1,
            num_classes=C))
    np.testing.assert_array_equal(acc, one_pass, err_msg=f"seed{seed}")
    np.testing.assert_array_equal(kacc, one_pass, err_msg=f"seed{seed}/k")


@pytest.mark.parametrize("seed,cuts", [
    (0, [100, 200]),                       # even-ish
    (1, [1, 2, 250]),                      # single-row chunks + long tail
    (2, [50, 50, 128]),                    # empty chunk in the middle
    (3, []),                               # one chunk == one pass
])
def test_chunked_table_accumulation_exact(seed, cuts):
    _check_chunked_accumulation(seed, cuts)


if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @given(st.integers(0, 10_000),
           st.lists(st.integers(0, 257), max_size=8))
    def test_property_chunked_accumulation(seed, cuts):
        _check_chunked_accumulation(seed, cuts)


# ---------------------------------------------------------------------------
# Sharded streaming parity (2x4 mesh, subprocess — pattern from
# tests/test_distributed.py)
# ---------------------------------------------------------------------------

def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_streaming_parity():
    """ShardedHistNumeric streaming (collective-free per-chunk shard_map
    accumulation, ONE psum per level) is bit-identical to the local
    engine's streamed fit AND to the in-memory sharded fit."""
    out = _run("""
        import numpy as np
        from repro.core import tree as tree_lib
        from repro.core.dataset import ArrayRowSource
        from repro.core.forest import RandomForest
        from repro.core.level.sharded import ShardedHistNumeric
        from repro.data.synthetic import make_tabular
        from repro.launch.mesh import make_host_mesh

        ds = make_tabular('xor', n=912, num_informative=5, num_useless=3,
                          seed=4)
        params = tree_lib.TreeParams(max_depth=5, split_mode='hist',
                                     num_bins=16, prune_closed_frac=0.5)
        eng = ShardedHistNumeric(mesh=make_host_mesh(2, 4))
        ref = RandomForest(params=params, num_trees=3, seed=7).fit(
            ds, engine=eng)
        src = ArrayRowSource.from_dataset(ds, params.num_bins,
                                          chunk_size=301)
        fs = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
            src, engine=eng)
        fl = RandomForest(params=params, num_trees=3, seed=7).fit_streamed(
            src)
        # fault-tolerance under the mesh engine (DESIGN.md §9): interrupt
        # a checkpointed sharded streamed fit with a persistent read
        # fault, resume from the snapshot, and land bit-identical
        import tempfile
        from repro.core.dataset import StreamReadError
        from repro.testing.faults import FaultyRowSource
        with tempfile.TemporaryDirectory() as ckdir:
            dead = FaultyRowSource(src, persistent={9})
            try:
                RandomForest(params=params, num_trees=3,
                             seed=7).fit_streamed(dead, engine=eng,
                                                  checkpoint_dir=ckdir)
                raise SystemExit('expected StreamReadError')
            except StreamReadError:
                pass
            fr = RandomForest(params=params, num_trees=3,
                              seed=7).fit_streamed(src, engine=eng,
                                                   checkpoint_dir=ckdir,
                                                   resume=True)
        for a, b in ((ref, fs), (fl, fs), (fr, fs)):
            for ta, tb in zip(a.trees, b.trees):
                assert ta.num_nodes == tb.num_nodes
                for f in ('feature', 'children', 'threshold', 'value',
                          'n_node', 'gain', 'depth'):
                    np.testing.assert_array_equal(getattr(ta, f),
                                                  getattr(tb, f), err_msg=f)
        print('SHARDED-STREAM-OK')
    """)
    assert "SHARDED-STREAM-OK" in out
