"""Histogram (PLANET-style) approximate split mode on the fused plumbing.

Contracts under test:
  * the bucket scorer (`splits.best_numeric_split_histogram`) matches a
    numpy brute-force over the same count table, and equals the EXACT
    search when every distinct value gets its own bucket;
  * hist thresholds are bucket edges, so training-time bucket partitions
    and inference-time `x <= thr` partitions agree exactly;
  * `tree.build_forest` under `split_mode="hist"` is bit-identical per
    tree to the per-tree fused builder — including uneven finish depths
    (early-finish masking) — and issues ONE batched level program per
    depth (mirrors tests/test_forest_batch.py for exact mode);
  * `split_mode="exact"` is the default and stays on the exact engines
    (tests/test_fused_level.py pins its bit-parity with the reference).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import presort, splits, tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.core.gbt import GBTModel, GBTParams
from repro.data.synthetic import make_tabular, train_test_split


def _build_kw(ds, seed=5):
    if ds.m_num:
        si = presort.presort_columns(ds.num)
        sv = presort.gather_sorted(ds.num, si)
    else:
        sv = jnp.zeros((0, ds.n), jnp.float32)
        si = jnp.zeros((0, ds.n), jnp.int32)
    return dict(num=ds.num, cat=ds.cat, labels=ds.labels, sorted_vals=sv,
                sorted_idx=si, arities=ds.arities,
                num_classes=ds.num_classes, seed=seed)


def _assert_identical(ta, tb, ctx=""):
    assert ta.num_nodes == tb.num_nodes, ctx
    for name in ("feature", "children", "threshold", "is_cat", "cat_mask",
                 "value", "n_node", "gain", "depth"):
        np.testing.assert_array_equal(getattr(ta, name), getattr(tb, name),
                                      err_msg=f"{ctx}:{name}")


@pytest.fixture(scope="module")
def mixed_ds():
    rng = np.random.default_rng(3)
    n = 1100
    num = rng.normal(size=(n, 4)).astype(np.float32)
    cat = rng.integers(0, 5, size=(n, 2)).astype(np.int32)
    y = ((num[:, 0] > 0) ^ (cat[:, 0] >= 3)).astype(np.int32)
    return from_numpy(num, cat, y)


# ---------------------------------------------------------------------------
# The bucket scorer vs numpy
# ---------------------------------------------------------------------------

def _np_imp_gini(h):
    n = h.sum(-1)
    return n - np.divide((h * h).sum(-1), n, out=np.zeros_like(n),
                         where=n > 0)


def test_hist_scorer_matches_numpy_bruteforce():
    rng = np.random.default_rng(0)
    L, B, C = 3, 12, 3
    table = rng.integers(0, 7, size=(L + 1, B, C)).astype(np.float32)
    table[1, :, 1:] = 0.0                         # single-class leaf
    table[2] = 0.0                                # empty leaf
    edges = np.sort(rng.normal(size=B)).astype(np.float32)
    cand = np.array([False] + [True] * L)
    g, t = splits.best_numeric_split_histogram(
        jnp.asarray(table), jnp.asarray(cand))
    g, t = np.asarray(g), np.asarray(t)
    tb = table.astype(np.float64)
    for h in range(1, L + 1):
        total = tb[h].sum(0)
        best_g, best_b = -np.inf, None
        for b in range(B - 1):
            left = tb[h, :b + 1].sum(0)
            right = total - left
            if left.sum() < 1 or right.sum() < 1:
                continue
            gb = (_np_imp_gini(total) - _np_imp_gini(left)
                  - _np_imp_gini(right))
            if gb > best_g:                       # first max wins
                best_g, best_b = gb, b
        if best_b is None:
            assert not np.isfinite(g[h]), h
            continue
        np.testing.assert_allclose(g[h], best_g, rtol=1e-5, atol=1e-5,
                                   err_msg=f"leaf{h}")
        # the scorer reports the BIN INDEX; the host decodes edges[cut]
        assert edges[int(t[h])] == edges[best_b], f"leaf{h}"


def test_hist_equals_exact_when_bins_cover_every_value():
    """One bucket per row: every boundary between distinct values is an
    edge, so the hist gains must equal the exact search's (thresholds are
    edges instead of midpoints — same partitions, same gains)."""
    rng = np.random.default_rng(4)
    n, L = 200, 3
    num = (np.round(rng.normal(size=(n, 2)) * 3) / 4).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    y = rng.integers(0, 2, n).astype(np.int32)
    stats = splits.row_stats(jnp.asarray(y), jnp.asarray(w), 2,
                             "classification")
    cand = np.ones((2, L + 1), bool)
    cand[:, 0] = False
    si = presort.presort_columns(jnp.asarray(num))
    sv = presort.gather_sorted(jnp.asarray(num), si)
    edges = presort.quantize_edges(sv, n)          # every row its own bucket
    bin_of = presort.bin_columns(jnp.asarray(num), edges)
    for j in range(2):
        g_h, cut_h = splits.best_numeric_split_histogram(
            splits.categorical_count_table(
                bin_of[j].astype(jnp.int32), jnp.asarray(leaf),
                jnp.asarray(w), stats, L, n),
            jnp.asarray(cand[j]))
        t_h = jnp.where(jnp.isfinite(g_h),
                        edges[j][cut_h.astype(jnp.int32)], 0.0)
        g_e, _ = splits.best_numeric_split_segment(
            sv[j], jnp.asarray(leaf)[si[j]], jnp.asarray(w)[si[j]],
            stats[si[j]], jnp.asarray(cand[j]), L)
        fin = np.isfinite(np.asarray(g_e))
        assert (np.isfinite(np.asarray(g_h)) == fin).all(), j
        np.testing.assert_allclose(np.asarray(g_h)[fin],
                                   np.asarray(g_e)[fin], rtol=1e-4,
                                   atol=1e-4, err_msg=f"col{j}")
        # hist thresholds must land on actual bucket edges
        for h in np.nonzero(fin)[0]:
            assert np.asarray(t_h)[h] in np.asarray(edges[j]), (j, h)


def test_bucket_partition_consistent_with_threshold_rule():
    """b(x) <= cut  <=>  x <= edges[cut]: the partition scored at training
    time is exactly the partition the tree applies at inference time."""
    rng = np.random.default_rng(8)
    num = np.round(rng.normal(size=(500, 3)) * 2).astype(np.float32) / 2
    si = presort.presort_columns(jnp.asarray(num))
    sv = presort.gather_sorted(jnp.asarray(num), si)
    for B in (2, 7, 32):
        edges = np.asarray(presort.quantize_edges(sv, B))
        bins = np.asarray(presort.bin_columns(jnp.asarray(num), edges))
        assert bins.min() >= 0 and bins.max() < B
        for j in range(3):
            for cut in range(B - 1):
                np.testing.assert_array_equal(
                    bins[j] <= cut, num[:, j] <= edges[j, cut],
                    err_msg=f"B{B}/col{j}/cut{cut}")


# ---------------------------------------------------------------------------
# The fused builders under split_mode="hist"
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["segment", "kernel"])
def test_hist_batched_matches_per_tree(mixed_ds, backend):
    """build_forest(hist) is bit-identical per tree to build_tree(hist),
    with uneven finish depths exercising the early-finish masking
    (satellite of the exact-mode contract in tests/test_forest_batch.py).
    The kernel backend routes the bucket tables through the Pallas
    cat_hist kernel with bins as the arity."""
    kw = _build_kw(mixed_ds)
    p = tree_lib.TreeParams(max_depth=5, min_records=60, backend=backend,
                            split_mode="hist", num_bins=8)
    trees, _ = tree_lib.build_forest(params=p, tree_indices=range(4), **kw)
    depths = {t.max_depth_reached for t in trees}
    assert len(depths) > 1, "fixture must exercise uneven finish depths"
    for t in range(4):
        solo, _ = tree_lib.build_tree(params=p, tree_idx=t, **kw)
        _assert_identical(trees[t], solo, f"hist/{backend}/tree{t}")


def test_hist_one_level_program_per_depth(mixed_ds):
    """fit(split_mode='hist') keeps the one-batched-program-per-depth
    property — dispatch- and trace-counted."""
    p = tree_lib.TreeParams(max_depth=4, split_mode="hist", num_bins=32)
    rf = RandomForest(p, num_trees=8, seed=0, tree_batch=8)
    rf.fit(mixed_ds)                                   # warm the jit caches

    calls0 = tree_lib._BATCH_STEP_CALLS[0]
    steps0 = tree_lib._STEP_CALLS[0]
    traces0 = tree_lib._BATCH_STEP_TRACES[0]
    rf2 = RandomForest(p, num_trees=8, seed=0, tree_batch=8).fit(mixed_ds)
    calls = tree_lib._BATCH_STEP_CALLS[0] - calls0
    D = max(t.max_depth_reached for t in rf2.trees)
    assert D <= calls <= p.max_depth + 1, (calls, D)
    assert tree_lib._STEP_CALLS[0] == steps0           # no per-tree fallback
    assert tree_lib._BATCH_STEP_TRACES[0] == traces0   # warm: no retrace
    for ta, tb in zip(rf.trees, rf2.trees):
        _assert_identical(ta, tb, "hist-warm-vs-cold")


def test_hist_thresholds_are_bucket_edges(mixed_ds):
    """Every numeric split a hist tree makes must use a quantizer edge."""
    B = 16
    bin_of, edges = mixed_ds.quantize(B)
    p = tree_lib.TreeParams(max_depth=5, split_mode="hist", num_bins=B)
    rf = RandomForest(p, num_trees=2, seed=1).fit(mixed_ds)
    edges = np.asarray(edges)
    checked = 0
    for tr in rf.trees:
        for i in range(tr.num_nodes):
            j = tr.feature[i]
            if j < 0 or tr.is_cat[i]:
                continue
            assert tr.threshold[i] in edges[j], (i, j)
            checked += 1
    assert checked > 0


def test_hist_close_to_exact_auc(mixed_ds):
    """The approximation-quality contract at test scale; the benchmark
    (benchmarks/run.py hist -> BENCH_hist_mode.json) records the headline
    num_bins=255 delta."""
    ds = make_tabular("majority", 4000, num_informative=4, num_useless=4,
                      seed=7)
    tr, te = train_test_split(ds)
    exact = RandomForest(tree_lib.TreeParams(max_depth=6), num_trees=8,
                         seed=3).fit(tr)
    hist = RandomForest(
        tree_lib.TreeParams(max_depth=6, split_mode="hist", num_bins=64),
        num_trees=8, seed=3).fit(tr)
    assert abs(exact.auc(te) - hist.auc(te)) < 0.02


def test_hist_pure_categorical_unaffected():
    """With no numeric columns hist mode degenerates to the exact builder
    (buckets only approximate numeric splits)."""
    rng = np.random.default_rng(0)
    n = 700
    cat = rng.integers(0, 6, size=(n, 3)).astype(np.int32)
    y = ((cat[:, 0] % 2) ^ (cat[:, 1] >= 3)).astype(np.int32)
    ds = from_numpy(None, cat, y)
    kw = _build_kw(ds)
    pe = tree_lib.TreeParams(max_depth=4)
    ph = tree_lib.TreeParams(max_depth=4, split_mode="hist", num_bins=16)
    te_, _ = tree_lib.build_tree(params=pe, tree_idx=0, **kw)
    th_, _ = tree_lib.build_tree(params=ph, tree_idx=0, **kw)
    _assert_identical(te_, th_, "pure-categorical")


def test_hist_with_row_pruning_still_consistent():
    """Sprint-style pruning under hist (per-tree builder): compaction must
    remap the bucket ids and leave the model unchanged."""
    rng = np.random.default_rng(0)
    n = 2000
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (num[:, 0] > 1.2).astype(np.int32)       # skewed: leaves close early
    ds = from_numpy(num, None, y)
    p = tree_lib.TreeParams(max_depth=8, min_records=50, split_mode="hist",
                            num_bins=32)
    base = RandomForest(p, num_trees=2, seed=3).fit(ds)
    import dataclasses
    pruned = RandomForest(dataclasses.replace(p, prune_closed_frac=0.3),
                          num_trees=2, seed=3).fit(ds)
    for ta, tb in zip(base.trees, pruned.trees):
        _assert_identical(ta, tb, "hist-pruned")


def test_hist_gbt_trains():
    rng = np.random.default_rng(1)
    n = 900
    num = rng.normal(size=(n, 4)).astype(np.float32)
    y = (2 * num[:, 0] + num[:, 1] ** 2).astype(np.float32)
    ds = from_numpy(num, None, y, task="regression")
    gbt = GBTModel(GBTParams(num_rounds=10, max_depth=3, learning_rate=0.3,
                             split_mode="hist", num_bins=64)).fit(ds)
    rmse = float(np.sqrt(((gbt.predict(ds.num, ds.cat) - y) ** 2).mean()))
    assert rmse < 0.5 * y.std()


def test_hist_rejects_bad_params():
    with pytest.raises(ValueError):
        tree_lib._tree_setup(jnp.zeros((0, 0), jnp.float32), (),
                             jnp.zeros((4,), jnp.int32),
                             tree_lib.TreeParams(split_mode="planet"))
    with pytest.raises(ValueError):
        tree_lib._tree_setup(jnp.zeros((0, 0), jnp.float32), (),
                             jnp.zeros((4,), jnp.int32),
                             tree_lib.TreeParams(split_mode="hist",
                                                 num_bins=1))


# ---------------------------------------------------------------------------
# Distributed hist supersplit (plumbing; the 8-device run is in
# tests/test_distributed.py under -m slow)
# ---------------------------------------------------------------------------

def test_hist_sharded_supersplit_single_device_mesh():
    """The psum-merged histogram supersplit on a 1x1 mesh must equal the
    local bucket search, end to end through a forest fit."""
    from repro.core import distributed
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    ds = make_tabular("xor", 600, num_informative=2, num_useless=2, seed=1)
    B = 32
    p = tree_lib.TreeParams(max_depth=4, split_mode="hist", num_bins=B)
    local = RandomForest(p, num_trees=2, seed=11).fit(ds)
    fn = distributed.make_hist_sharded_supersplit(mesh)
    dist = RandomForest(p, num_trees=2, seed=11).fit(ds, supersplit_fn=fn)
    for ta, tb in zip(local.trees, dist.trees):
        _assert_identical(ta, tb, "hist-sharded-1x1")
