"""Training loop + serving engine + checkpoint behaviour."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_arch
from repro.data.synthetic import TokenStream
from repro.models import transformer
from repro.optim import adamw
from repro.serve import engine
from repro.train import step as tsl


def test_chunked_ce_equals_full():
    key = jax.random.PRNGKey(0)
    B, S, D, V = 2, 32, 16, 50
    x = jax.random.normal(key, (B, S, D))
    w = jax.random.normal(key, (D, V)) * 0.1
    labels = jax.random.randint(key, (B, S), 0, V)
    full = tsl.cross_entropy(jnp.einsum("bsd,dv->bsv", x, w), labels, 1e-4)
    for nc in (1, 2, 8):
        chunked = tsl.chunked_cross_entropy(x, w, labels, 1e-4, nc)
        assert float(chunked) == pytest.approx(float(full), rel=1e-5)


def test_loss_decreases_quick():
    cfg = get_arch("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, d_ff=256,
                              vocab_size=128, head_dim=32)
    tcfg = tsl.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        ce_chunks=2)
    state = tsl.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(tsl.make_train_step(cfg, tcfg), donate_argnums=0)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=1)
    losses = []
    for i, raw in zip(range(60), stream):
        batch = {"inputs": jnp.asarray(raw["inputs"]),
                 "labels": jnp.asarray(raw["labels"])}
        state, m = step(state, batch)
        losses.append(float(m["ce"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses[::10]


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, s)) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("granite-3-2b").reduced()
    tcfg = tsl.TrainConfig()
    state = tsl.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    ckpt_io.save(path, state)
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored = ckpt_io.restore(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batched_server_decodes():
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              num_layers=2, d_model=64, d_ff=128,
                              vocab_size=64, head_dim=16)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    srv = engine.BatchedServer(cfg=cfg, params=params, max_seq=32, batch=2)
    s0 = srv.add_request([1, 2, 3])
    s1 = srv.add_request([4, 5])
    for _ in range(4):
        out = srv.step()
        assert set(out) == {s0, s1}
        assert all(0 <= t < cfg.vocab_size for t in out.values())
    toks = srv.finish(s0)
    assert len(toks) == 4
