"""Multi-host (jax.distributed) smoke run — ROADMAP follow-up.

Boots N local processes into one jax.distributed cluster and trains a
tiny sharded-hist forest through `build_forest` in each, asserting
equality with the single-process result and cross-process agreement (see
repro/launch/multihost_smoke.py for the global-mesh vs local-mesh modes —
the CPU backend has no cross-process collectives, so CI proves boot +
determinism and TPU/GPU boxes prove the cross-process psum too).
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_multihost_smoke_two_processes():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.multihost_smoke", "--nproc",
         "2"],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "multihost smoke: 2 processes OK" in out.stdout, out.stdout
