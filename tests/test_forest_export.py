"""PackedForest stable export path (ROADMAP "Serving"): one versioned
.npz round-trips bit-exactly and serves batched inference with no Tree
objects or training code in the loop."""
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.forest import PackedForest, RandomForest
from repro.data.synthetic import make_tabular


@pytest.fixture(scope="module")
def fitted():
    ds = make_tabular("xor", 800, num_informative=2, num_useless=2, seed=0)
    rf = RandomForest(tree_lib.TreeParams(max_depth=4), num_trees=6,
                      seed=1).fit(ds)
    return ds, rf


def test_save_load_roundtrip_bit_exact(fitted, tmp_path):
    ds, rf = fitted
    path = tmp_path / "forest.npz"
    rf.packed.save(path)
    loaded = PackedForest.load(path)
    assert loaded.num_trees == rf.packed.num_trees
    assert loaded.m_num == rf.packed.m_num
    assert loaded.iters == rf.packed.iters
    for k in PackedForest._ARRAYS:
        np.testing.assert_array_equal(np.asarray(getattr(loaded, k)),
                                      np.asarray(getattr(rf.packed, k)),
                                      err_msg=k)


def test_loaded_forest_predicts_identically(fitted, tmp_path):
    ds, rf = fitted
    path = tmp_path / "forest.npz"
    rf.packed.save(path)
    loaded = PackedForest.load(path)
    p_mem = np.asarray(rf.predict_proba(ds.num, ds.cat))
    p_load = np.asarray(loaded.predict_proba(ds.num, ds.cat))
    np.testing.assert_array_equal(p_mem, p_load)
    # per-tree view too (serving's OOB/analysis path)
    np.testing.assert_array_equal(
        np.asarray(rf.predict_proba_per_tree(ds.num, ds.cat)),
        np.asarray(loaded.predict_proba(ds.num, ds.cat,
                                        reduce_mean=False)))


def test_load_rejects_unknown_version(fitted, tmp_path):
    ds, rf = fitted
    path = tmp_path / "forest.npz"
    rf.packed.save(path)
    with np.load(path) as z:
        blob = {k: z[k] for k in z.files}
    blob["format_version"] = np.int32(999)
    bad = tmp_path / "bad.npz"
    np.savez_compressed(bad, **blob)
    with pytest.raises(ValueError, match="format v999"):
        PackedForest.load(bad)


def test_export_example_runs(tmp_path):
    """The examples/ entry is executable documentation — keep it green."""
    import subprocess
    import sys
    import os
    here = os.path.dirname(__file__)
    out = subprocess.run(
        [sys.executable, os.path.join(here, "..", "examples",
                                      "forest_export.py")],
        capture_output=True, text=True, timeout=600,
        cwd=tmp_path,
        env=dict(os.environ,
                 PYTHONPATH=os.path.join(here, "..", "src")))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "round-trip verified" in out.stdout
