"""Per-architecture smoke tests (assignment requirement f): REDUCED variant
of each family — 2 layers (or one pattern period), d_model<=512, <=4
experts — one forward + one train step on CPU, asserting shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_arch, list_archs
from repro.models import transformer
from repro.train import step as train_step_lib

ARCHS = list_archs()


def _inputs(cfg, key, B=2, S=32):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)


ASSIGNED = {
    "chatglm3-6b", "qwen3-0.6b", "granite-3-2b", "rwkv6-7b",
    "jamba-1.5-large-398b", "musicgen-medium", "llama3-8b", "olmoe-1b-7b",
    "dbrx-132b", "llava-next-mistral-7b",
}


def test_all_ten_archs_assigned():
    assert ASSIGNED <= set(ARCHS)          # + extra variants (llama3-8b-sw8k)
    fams = {get_arch(a).family for a in ASSIGNED}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_no_nan(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, S = 2, 32
    inputs = _inputs(cfg, key, B, S)
    logits, aux, _ = transformer.forward(params, inputs, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(1)
    tcfg = train_step_lib.TrainConfig(ce_chunks=4)
    state = train_step_lib.init_train_state(key, cfg, tcfg)
    B, S = 2, 32
    batch = {"inputs": _inputs(cfg, key, B, S),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    step = jax.jit(train_step_lib.make_train_step(cfg, tcfg))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["grad_norm"]) > 0
    # params actually changed
    before = transformer.param_count(state["params"])
    assert before > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(key, cfg)
    B, S_max = 2, 16
    caches = transformer.init_cache(cfg, B, S_max)
    inputs = _inputs(cfg, key, B, 1)
    logits, new_caches = transformer.decode_step(
        params, caches, inputs, jnp.zeros((B,), jnp.int32), cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree_util.tree_structure(caches) == \
        jax.tree_util.tree_structure(new_caches)


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"] == dict(seq_len=4096, global_batch=256,
                                            kind="train")
    assert INPUT_SHAPES["long_500k"]["seq_len"] == 524288
