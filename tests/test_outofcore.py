"""Disk-backed streaming training at moderate n (`-m outofcore`).

Excluded from tier-1 (disk-heavy); run explicitly with
``pytest -m outofcore``.  Exercises the whole out-of-core path end to
end at a size where multiple chunks, multiple levels, and the memmap
round-trip all matter: deterministic per-chunk generation -> 3-pass
streaming quantizer -> uint8 bin cache on disk -> `fit_streamed` — and
cross-checks the result against an in-memory fit of the SAME generated
data, so the test certifies the full chain, not just that it runs.
"""
import os

import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.dataset import MemmapRowSource, from_numpy
from repro.core.forest import RandomForest

pytestmark = pytest.mark.outofcore


@pytest.mark.parametrize("n", [200_000])
def test_disk_backed_fit_moderate_n(tmp_path, n):
    m, chunk = 6, 1 << 14

    def chunks():
        for i, lo in enumerate(range(0, n, chunk)):
            c = min(chunk, n - lo)
            rng = np.random.default_rng(100 + i)
            yield rng.normal(size=(c, m)).astype(np.float32)

    y = np.empty(n, np.int32)
    lo = 0
    for block in chunks():
        y[lo:lo + len(block)] = ((block[:, :3] > 0).sum(1) >= 2)
        lo += len(block)

    params = tree_lib.TreeParams(max_depth=6, split_mode="hist",
                                 num_bins=32)
    path = str(tmp_path / "bins.npy")
    src = MemmapRowSource.build(chunks, n, y, num_bins=params.num_bins,
                                path=path, num_classes=2, chunk_size=chunk)
    assert os.path.getsize(path) >= n * m          # uint8 cache really on disk
    fs = RandomForest(params=params, num_trees=2, seed=11).fit_streamed(src)

    # the in-memory reference on the same data: identical trees
    num = np.concatenate(list(chunks()), axis=0)
    ref = RandomForest(params=params, num_trees=2, seed=11).fit(
        from_numpy(num, None, y))
    for t, (ta, tb) in enumerate(zip(ref.trees, fs.trees)):
        assert ta.num_nodes == tb.num_nodes, t
        for f in ("feature", "children", "threshold", "value", "n_node",
                  "gain", "depth"):
            np.testing.assert_array_equal(getattr(ta, f), getattr(tb, f),
                                          err_msg=f"tree{t}/{f}")
    # a real multi-level, multi-chunk run
    assert max(tr.depth.max() for tr in fs.trees) >= 3
