"""class_list / bagging / presort unit + property tests.

`hypothesis` is an OPTIONAL dev dependency (see DESIGN.md §Testing): when
absent this whole module is skipped at collection instead of erroring the
run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bagging, class_list, presort


# ---------------------------------------------------------------------------
# class list (paper §2.3)
# ---------------------------------------------------------------------------

@pytest.mark.hypothesis
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 60_000), st.integers(0, 2**31 - 1))
def test_pack_roundtrip(n, num_leaves, seed):
    rng = np.random.default_rng(seed)
    bits = class_list.bits_needed(num_leaves)
    ids = rng.integers(0, num_leaves + 1, n).astype(np.int32)
    packed = class_list.pack(jnp.asarray(ids), bits)
    un = class_list.unpack(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(un), ids)


def test_bits_needed_matches_paper():
    # ⌈log2(ℓ+1)⌉ — table of hand-checked values
    assert class_list.bits_needed(1) == 1
    assert class_list.bits_needed(3) == 2
    assert class_list.bits_needed(4) == 3
    assert class_list.bits_needed(7) == 3
    assert class_list.bits_needed(8) == 4


def test_storage_is_logarithmic():
    n = 10_000
    # far below 64 bits per sample for realistic leaf counts (paper §2.3)
    assert class_list.storage_bits(n, 1023) == n * 10
    words = class_list.packed_words(n, 10)
    # no-straddle packing wastes at most (32 mod bits) bits per word (<7%)
    assert words * 32 <= n * 10 * 32 / 30 + 64


# ---------------------------------------------------------------------------
# seeded bagging (paper §2.2)
# ---------------------------------------------------------------------------

def test_bagging_deterministic_across_workers():
    """Two 'workers' derive the same bag from the seed — zero communication."""
    a = bagging.bag_counts(42, 7, 1000, "poisson")
    b = bagging.bag_counts(42, 7, 1000, "poisson")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = bagging.bag_counts(42, 8, 1000, "poisson")
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_bagging_modes():
    n = 5000
    w = np.asarray(bagging.bag_counts(0, 0, n, "poisson"))
    assert 0.9 < w.mean() < 1.1
    w2 = np.asarray(bagging.bag_counts(0, 0, n, "multinomial"))
    assert w2.sum() == n                      # exactly n-out-of-n
    w3 = np.asarray(bagging.bag_counts(0, 0, n, "none"))
    assert (w3 == 1).all()


def test_candidate_features_counts_and_usb():
    key = jax.random.PRNGKey(0)
    m, mp, L = 20, 5, 6
    cand = np.asarray(bagging.candidate_features(key, 3, L, m, mp, usb=False))
    assert cand.shape == (L, m)
    assert (cand.sum(1) == mp).all()
    usb = np.asarray(bagging.candidate_features(key, 3, L, m, mp, usb=True))
    assert (usb == usb[0]).all()              # z = 1: same set for all leaves


# ---------------------------------------------------------------------------
# presort (paper §2.1)
# ---------------------------------------------------------------------------

def test_presort_sorted_and_stable(rng):
    num = rng.normal(size=(500, 3)).astype(np.float32)
    num[::7, 1] = 1.0                         # ties for stability check
    si = np.asarray(presort.presort_columns(jnp.asarray(num)))
    sv = np.asarray(presort.gather_sorted(jnp.asarray(num), jnp.asarray(si)))
    for j in range(3):
        assert (np.diff(sv[j]) >= 0).all()
        ties = si[1][num[si[1], 1] == 1.0]
        assert (np.diff(ties) > 0).all()      # stable: original order kept
