"""Distributed DRF: train the SAME forest with the 2-D sharded supersplit
engine (feature columns over "model" splitters, presorted rows over "data")
and verify it is bit-identical to the single-machine build — the paper's
exactness guarantee, demonstrated on an 8-device host mesh.

  python examples/distributed_forest.py      (sets its own XLA_FLAGS)
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import distributed, tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular
from repro.launch.mesh import make_host_mesh


def main() -> None:
    mesh = make_host_mesh(data=2, model=4)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices)")

    ds = make_tabular("majority", 4000, num_informative=6, num_useless=2,
                      seed=3)
    params = tree_lib.TreeParams(max_depth=6, min_records=2)

    local = RandomForest(params, num_trees=3, seed=7).fit(ds)
    sup = distributed.make_2d_sharded_supersplit(mesh)
    dist = RandomForest(params, num_trees=3, seed=7).fit(ds, supersplit_fn=sup)

    for i, (a, b) in enumerate(zip(local.trees, dist.trees)):
        same = (a.num_nodes == b.num_nodes
                and (a.feature == b.feature).all()
                and np.allclose(a.threshold, b.threshold, atol=1e-4))
        print(f"tree {i}: local={a.num_nodes} nodes, "
              f"distributed={b.num_nodes} nodes, identical={same}")
        assert same, "distributed training must be EXACT (paper's guarantee)"

    print(f"distributed AUC: {dist.auc(ds):.4f} "
          f"(== local {local.auc(ds):.4f})")
    print("exact distributed training verified ✓")


if __name__ == "__main__":
    main()
