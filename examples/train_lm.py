"""End-to-end training driver (deliverable (b)): train an LM with the full
stack — config, data pipeline, AdamW, remat, chunked CE, checkpointing.

Default is CPU-sized (a few M params, 150 steps, loss must drop).  On real
hardware run the ~100M configuration:

  PYTHONPATH=src python examples/train_lm.py                  # CPU demo
  PYTHONPATH=src python examples/train_lm.py --hundred-m      # ~100M params
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.base import get_arch
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param config (run on real hardware)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.hundred_m:
        cfg = dataclasses.replace(
            get_arch("qwen3-0.6b"),
            num_layers=12, d_model=768, d_ff=2048, num_heads=12,
            num_kv_heads=4, head_dim=64, vocab_size=32768, dtype="float32")
        steps = args.steps or 300
        batch, seq = 16, 512
    else:
        cfg = dataclasses.replace(
            get_arch("qwen3-0.6b").reduced(),
            num_layers=2, d_model=128, d_ff=384, vocab_size=512,
            head_dim=32)
        steps = args.steps or 150
        batch, seq = 8, 64

    _, losses = train_loop(cfg, steps=steps, batch=batch, seq=seq, lr=1e-3,
                           checkpoint_path="/tmp/repro_lm_ckpt.npz",
                           ce_chunks=4, log_every=25)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"ce: first10={first:.4f} last10={last:.4f}")
    assert last < first, "loss should decrease"
    print("training improved the loss ✓  (checkpoint at /tmp/repro_lm_ckpt.npz)")


if __name__ == "__main__":
    main()
