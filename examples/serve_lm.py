"""End-to-end serving driver: serve a small LM with batched requests
(deliverable (b): "serve a small model with batched requests").

Prefills each request, then decodes all active slots together every step —
the same prefill/decode path the 32k/500k dry-runs lower for the pod.

  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import transformer
from repro.serve import engine


def main() -> None:
    cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(),
                              num_layers=2, d_model=128, d_ff=256,
                              vocab_size=512, head_dim=32)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n_params = transformer.param_count(params)
    print(f"serving {cfg.name}: {n_params/1e6:.2f}M params")

    srv = engine.BatchedServer(cfg=cfg, params=params, max_seq=64, batch=4)

    prompts = {
        "req-A": [11, 45, 89, 200],
        "req-B": [7, 3],
        "req-C": [100, 101, 102, 103, 104],
    }
    slots = {}
    for name, toks in prompts.items():
        slots[name] = srv.add_request(toks)
        print(f"{name}: prefilled {len(toks)} tokens -> slot {slots[name]}")

    t0 = time.time()
    steps = 12
    for i in range(steps):
        out = srv.step()
        if i < 3:
            print(f"step {i}: decoded {dict(sorted(out.items()))}")
    dt = time.time() - t0
    active = sum(1 for _ in prompts)
    print(f"{steps} batched decode steps x {active} requests in {dt:.2f}s "
          f"({steps*active/dt:.1f} tok/s on 1 CPU)")

    for name, slot in slots.items():
        toks = srv.finish(slot)
        print(f"{name}: generated {toks}")


if __name__ == "__main__":
    main()
