"""Gradient Boosted Trees on the DRF substrate (paper §1: "the proposed
algorithm can be applied to other DF models, notably GBT").

  PYTHONPATH=src python examples/gbt_regression.py
"""
import numpy as np

from repro.core.dataset import from_numpy
from repro.core.gbt import GBTModel, GBTParams


def main() -> None:
    rng = np.random.default_rng(0)
    n = 4000
    num = rng.normal(size=(n, 6)).astype(np.float32)
    y = (np.sin(num[:, 0] * 2) + 0.5 * num[:, 1] ** 2 + num[:, 2]
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    cut = 3 * n // 4
    train = from_numpy(num[:cut], None, y[:cut], task="regression")
    test = from_numpy(num[cut:], None, y[cut:], task="regression")

    gbt = GBTModel(GBTParams(num_rounds=30, max_depth=4,
                             learning_rate=0.2)).fit(train)
    pred = gbt.predict(test.num, test.cat)
    rmse = float(np.sqrt(((pred - y[cut:]) ** 2).mean()))
    base = float(y[cut:].std())
    print(f"GBT rounds=30 depth=4  test RMSE={rmse:.3f} "
          f"(constant-predictor baseline {base:.3f})")
    assert rmse < 0.5 * base

    # binary classification with logistic loss
    yb = (num[:, 0] + num[:, 1] > 0).astype(np.int32)
    tr = from_numpy(num[:cut], None, yb[:cut])
    te = from_numpy(num[cut:], None, yb[cut:])
    g2 = GBTModel(GBTParams(num_rounds=20, max_depth=3, learning_rate=0.3,
                            loss="logistic")).fit(tr)
    acc = float((g2.predict(te.num, te.cat) == yb[cut:]).mean())
    print(f"GBT logistic  test acc={acc:.3f}")


if __name__ == "__main__":
    main()
