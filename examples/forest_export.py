"""Export a trained forest to ONE .npz file and serve batched inference
from the loaded arrays — the ROADMAP "Serving" path.

`RandomForest.fit` packs every tree into a `PackedForest` (padded
(T, N, ...) device arrays); `save`/`load` round-trips that pack through a
single versioned .npz with no pickle and no Tree objects, and
`PackedForest.predict_proba` is ONE jitted vmap-over-trees descent — the
whole forest answers a batch in a single device program.

  PYTHONPATH=src python examples/forest_export.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import tree as tree_lib
from repro.core.forest import PackedForest, RandomForest
from repro.data.synthetic import make_tabular, train_test_split


def main() -> None:
    ds = make_tabular("majority", 4000, num_informative=4, num_useless=4,
                      seed=3)
    train, test = train_test_split(ds)
    rf = RandomForest(tree_lib.TreeParams(max_depth=8), num_trees=20,
                      seed=42).fit(train)
    print(f"trained {rf.num_trees} trees, AUC {rf.auc(test):.4f}")

    path = "forest_export.npz"
    rf.packed.save(path)
    size_kb = os.path.getsize(path) / 1024
    print(f"saved {path} ({size_kb:.0f} KiB, "
          f"format v{PackedForest.FORMAT_VERSION})")

    # a serving process needs only the .npz — no training state, no Trees
    loaded = PackedForest.load(path)
    p_mem = np.asarray(rf.predict_proba(test.num, test.cat))
    p_load = np.asarray(loaded.predict_proba(test.num, test.cat))
    np.testing.assert_array_equal(p_mem, p_load)
    print(f"batched inference on {p_load.shape[0]} rows from the loaded "
          f"pack: one jitted call, round-trip verified ✓")
    os.remove(path)


if __name__ == "__main__":
    main()
