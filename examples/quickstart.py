"""Quickstart: train an exact Random Forest (the paper's DRF) on a synthetic
classification task and inspect it.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular, train_test_split


def main() -> None:
    # xor family with useless variables — rote learning fails here (Fig. 1)
    ds = make_tabular("xor", n=6000, num_informative=2, num_useless=8, seed=0)
    train, test = train_test_split(ds)

    rf = RandomForest(
        tree_lib.TreeParams(
            max_depth=12,
            min_records=1,
            backend="segment",     # exact TPU-native supersplit engine
        ),
        num_trees=10, seed=42,
    ).fit(train)

    pred = np.asarray(rf.predict(test.num, test.cat))
    acc = (pred == np.asarray(test.labels)).mean()
    print(f"test accuracy : {acc:.4f}")
    print(f"test AUC      : {rf.auc(test):.4f}")
    print(f"OOB accuracy  : {rf.oob_score(train):.4f}")
    print(f"tree 0        : {rf.trees[0].num_nodes} nodes, "
          f"{rf.trees[0].num_leaves} leaves, "
          f"depth {rf.trees[0].max_depth_reached}")
    imp = rf.feature_importances()
    print(f"importances   : informative={imp[:2].sum():.3f} "
          f"useless={imp[2:].sum():.3f}")
    assert imp[:2].sum() > imp[2:].sum(), "informative features should win"


if __name__ == "__main__":
    main()
