"""Compose the two pillars: a transformer encodes sequences, DRF trains an
exact Random Forest on the frozen embeddings (deep features + forests —
the classic deployment the paper's Leo setting resembles).

  PYTHONPATH=src python examples/rf_on_embeddings.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import tree as tree_lib
from repro.core.dataset import from_numpy
from repro.core.forest import RandomForest
from repro.models import transformer


def main() -> None:
    # tiny frozen transformer as a feature extractor
    cfg = dataclasses.replace(get_arch("granite-3-2b").reduced(),
                              num_layers=2, d_model=64, d_ff=128,
                              vocab_size=64, head_dim=16, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)

    # synthetic task: does the sequence contain token 7 before token 9?
    rng = np.random.default_rng(1)
    n, S = 3000, 16
    toks = rng.integers(0, cfg.vocab_size, size=(n, S)).astype(np.int32)
    pos7 = np.where((toks == 7).any(1), (toks == 7).argmax(1), S + 1)
    pos9 = np.where((toks == 9).any(1), (toks == 9).argmax(1), S + 1)
    y = (pos7 < pos9).astype(np.int32)

    @jax.jit
    def embed(t):
        x, _, _ = transformer.forward_hidden(params, t, cfg)
        return x.mean(axis=1)                      # (B, D) pooled features

    feats = np.asarray(jnp.concatenate(
        [embed(jnp.asarray(toks[i:i + 512])) for i in range(0, n, 512)]))
    print(f"embedded {n} sequences -> features {feats.shape}")

    cut = 3 * n // 4
    train = from_numpy(feats[:cut], None, y[:cut])
    test = from_numpy(feats[cut:], None, y[cut:])
    rf = RandomForest(tree_lib.TreeParams(max_depth=10, min_records=2),
                      num_trees=8, seed=0).fit(train)
    acc = float((np.asarray(rf.predict(test.num, test.cat)) == y[cut:]).mean())
    base = max(y[cut:].mean(), 1 - y[cut:].mean())
    print(f"RF-on-embeddings test acc={acc:.3f} (majority baseline {base:.3f})")
    print(f"AUC={rf.auc(test):.3f}")


if __name__ == "__main__":
    main()
