"""Paper Fig. 2: training time as a function of training-set size.

The paper reports near-linear scaling of tree-build time in n (the
per-level passes are O(candidate-features × n)).  We measure wall time per
tree at increasing n and report the local scaling exponent."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular


def run(full: bool = False):
    sizes = [1000, 4000, 16000] if not full else [4000, 16000, 64000, 256000]
    times = []
    for n in sizes:
        ds = make_tabular("majority", n, num_informative=4, num_useless=4,
                          seed=7)
        p = tree_lib.TreeParams(max_depth=8, min_records=1)
        # warm the jit caches with a first fit, then time
        RandomForest(p, num_trees=1, seed=0).fit(ds)
        t0 = time.perf_counter()
        RandomForest(p, num_trees=1, seed=1).fit(ds)
        dt = time.perf_counter() - t0
        times.append(dt)
        emit(f"fig2/train_time/n{n}", dt * 1e6, f"s_per_tree={dt:.3f}")
    exps = [np.log(times[i + 1] / times[i]) / np.log(sizes[i + 1] / sizes[i])
            for i in range(len(sizes) - 1)]
    emit("fig2/scaling_exponent", 0.0,
         f"exponents={[round(e, 2) for e in exps]};"
         f"claim=near-linear (<=1.3): "
         f"{'OK' if max(exps) < 1.3 else 'NOTE-superlinear-at-bench-scale'}")
    return times


def main() -> None:
    run()


if __name__ == "__main__":
    main()
