"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (repo convention).
Roofline terms come from the dry-run (launch/dryrun.py) — see
roofline_report.py and EXPERIMENTS.md §Roofline.

Usage:
  python -m benchmarks.run                  # every benchmark, full scale
  python -m benchmarks.run all --smoke      # every benchmark, seconds-scale
  python -m benchmarks.run forest --smoke   # one benchmark
  python -m benchmarks.run dist             # sharded batched-vs-per-tree

Perf-regression gate: ``python -m benchmarks.check_regression`` re-runs
the smoke benchmarks and fails on >2× slowdown vs the committed
``BENCH_smoke_baseline.json`` (wired into ``pytest -m slow``).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (dist_batch_bench, fig1_auc_scaling,
                            fig2_time_scaling, fig3_depth_metrics,
                            forest_batch_bench, hist_mode_bench,
                            kernel_bench, level_step_bench,
                            outofcore_bench, serve_bench,
                            table1_complexity)
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    flags = {a for a in sys.argv[1:] if a.startswith("--")}
    unknown = flags - {"--smoke", "--full", "--checkpoint"}
    if unknown:
        raise SystemExit(f"unknown flags: {sorted(unknown)} "
                         "(supported: --smoke, --full, --checkpoint)")
    only = args[0] if args else None
    if only == "all":           # explicit umbrella (same as no selector)
        only = None
    smoke = "--smoke" in flags
    full = "--full" in flags
    benches = {
        "table1": table1_complexity.run,
        "fig2": fig2_time_scaling.run,
        "fig3": fig3_depth_metrics.run,
        "kernel": kernel_bench.run,
        "fig1": fig1_auc_scaling.run,
        # writes BENCH_level_step.json (fused vs reference per-level time)
        "level": level_step_bench.run,
        # writes BENCH_forest_batch.json (batched vs per-tree forest fit);
        # honours --smoke (seconds-scale) and --full (adds the 250k point)
        "forest": lambda: forest_batch_bench.run(full=full, smoke=smoke),
        # writes BENCH_hist_mode.json (exact vs PLANET-style histogram
        # mode: AUC delta + fit-wall matrix); honours --smoke
        "hist": lambda: hist_mode_bench.run(smoke=smoke),
        # writes BENCH_dist_batch.json (sharded training: batched vs
        # per-tree level programs on the 2x4 host mesh); honours --smoke
        "dist": lambda: dist_batch_bench.run(smoke=smoke),
        # writes BENCH_serve.json (ForestServer.load + p50 single-row
        # predict latency off the warm packed-forest descent)
        "serve": lambda: serve_bench.run(smoke=smoke),
        # writes BENCH_outofcore.json (streamed fit from a disk-backed
        # bin cache: rows/sec vs n, target n >= 20M); honours --smoke;
        # --checkpoint adds a checkpointed fit per point and records the
        # checkpoint-write overhead fraction (smoke always measures it)
        "outofcore": lambda: outofcore_bench.run(smoke=smoke,
                                                 checkpoint="--checkpoint"
                                                 in flags),
    }
    if only and only not in benches:
        raise SystemExit(f"unknown benchmark {only!r} "
                         f"(have: {', '.join(benches)}, or 'all')")
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name != only:
            continue
        t0 = time.time()
        fn()
        print(f"bench/{name}/wall,{(time.time() - t0) * 1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
