"""Out-of-core streaming training: rows/sec vs n with the bin cache on disk.

The claim under test (ISSUE 7 acceptance): a streamed hist-mode fit
completes at n >= 20M on the 2-core CI box with peak DEVICE memory
independent of n — bounded by `chunk_size`, because the level programs
only ever see fixed-shape chunk buffers of the uint8 bin cache while all
per-row state (labels, bag weights, leaf ids) stays host-resident
(DESIGN.md §8).

For each point n the benchmark
  1. generates the float data chunk-by-chunk from a DETERMINISTIC
     per-chunk generator (``default_rng(seed + chunk_index)``) so no
     (n, m) float32 array is ever materialized — the generator is
     re-iterated for each of the quantizer's passes exactly as a
     production loader would re-scan a file;
  2. builds a `MemmapRowSource` on disk (3 radix-select quantile passes
     + 1 bin-write pass, `presort.streaming_quantile_edges`), timing the
     build wall;
  3. trains a forest with `fit_streamed` (``bagging="none"`` so even the
     per-tree bag draw is chunk-bounded) and records the fit wall,
     ``rows_per_sec = n * trees / fit_s``, the streamed chunk-program
     dispatch/trace counters, and peak host RSS.

Writes ``BENCH_outofcore.json``.  Smoke mode shrinks the curve to a
seconds-scale pair of points for the regression gate.
"""
from __future__ import annotations

import json
import os
import resource
import tempfile
import time

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_OUTOFCORE_JSON", "BENCH_outofcore.json")

M_NUM = 8
NUM_BINS = 64
SEED = 17


def _chunk_gen(n, chunk, seed):
    """Deterministic re-iterable chunk stream: block i is a pure function
    of (seed, i), so every quantizer pass sees identical bytes without a
    full array ever existing."""
    def chunks():
        for i, lo in enumerate(range(0, n, chunk)):
            c = min(chunk, n - lo)
            rng = __import__("numpy").random.default_rng(seed + i)
            yield rng.normal(size=(c, M_NUM)).astype("float32")
    return chunks


def _labels_for(chunks, n):
    """y = majority-of-first-4 — derived chunk-by-chunk from the stream."""
    import numpy as np
    y = np.empty(n, np.int32)
    lo = 0
    for block in chunks():
        c = len(block)
        y[lo:lo + c] = ((block[:, :4] > 0).sum(1) >= 2).astype(np.int32)
        lo += c
    assert lo == n
    return y


def _bench_point(n, trees, depth, chunk, workdir, with_checkpoint=False):
    import numpy as np

    from repro.core import checkpoint as ckpt_mod
    from repro.core import tree as tree_lib
    from repro.core.dataset import MemmapRowSource
    from repro.core.forest import RandomForest
    from repro.core.level import plan as plan_mod

    chunks = _chunk_gen(n, chunk, SEED)
    y = _labels_for(chunks, n)

    path = os.path.join(workdir, f"bins_{n}.npy")
    t0 = time.perf_counter()
    src = MemmapRowSource.build(chunks, n, y, num_bins=NUM_BINS, path=path,
                                num_classes=2, chunk_size=chunk)
    build_s = time.perf_counter() - t0
    cache_mb = os.path.getsize(path) / 1e6

    params = tree_lib.TreeParams(max_depth=depth, split_mode="hist",
                                 num_bins=NUM_BINS, bagging="none")
    c0 = plan_mod._STREAM_CHUNK_CALLS[0]
    t1 = plan_mod._STREAM_CHUNK_TRACES[0]
    t0 = time.perf_counter()
    RandomForest(params=params, num_trees=trees, seed=3).fit_streamed(src)
    fit_s = time.perf_counter() - t0
    calls = plan_mod._STREAM_CHUNK_CALLS[0] - c0
    traces = plan_mod._STREAM_CHUNK_TRACES[0] - t1

    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    rows_per_sec = n * trees / fit_s
    emit(f"outofcore/fit/n{n}", fit_s * 1e6,
         f"rows_per_sec={rows_per_sec:.0f};chunks={calls};traces={traces};"
         f"build={build_s:.1f}s;rss={rss_mb:.0f}MB")
    point = {
        "n": n, "trees": trees, "max_depth": depth, "chunk_size": chunk,
        "build_s": round(build_s, 3), "bin_cache_mb": round(cache_mb, 1),
        "fit_s": round(fit_s, 3), "rows_per_sec": round(rows_per_sec, 1),
        "chunk_programs": calls, "chunk_traces": traces,
        "peak_rss_mb": round(rss_mb, 1),
    }

    if with_checkpoint:
        # Same fit with per-level snapshots flushed to disk.  Overhead is
        # reported as the fraction of the checkpointed wall spent inside
        # checkpoint writes (CKPT_WALL times every manifest/trees/snapshot
        # write), which is far less noisy on a loaded box than the ratio
        # of two independently-measured walls.
        ckdir = os.path.join(workdir, f"ck_{n}")
        w0 = ckpt_mod.CKPT_WALL[0]
        t0 = time.perf_counter()
        RandomForest(params=params, num_trees=trees, seed=3).fit_streamed(
            src, checkpoint_dir=ckdir, checkpoint_every=1)
        fit_ckpt_s = time.perf_counter() - t0
        ckpt_write_s = ckpt_mod.CKPT_WALL[0] - w0
        frac = ckpt_write_s / fit_ckpt_s
        emit(f"outofcore/fit_ckpt/n{n}", fit_ckpt_s * 1e6,
             f"ckpt_write={ckpt_write_s:.3f}s;overhead_frac={frac:.4f}")
        for f in os.listdir(ckdir):
            os.remove(os.path.join(ckdir, f))
        os.rmdir(ckdir)
        point.update({
            "fit_ckpt_s": round(fit_ckpt_s, 3),
            "ckpt_write_s": round(ckpt_write_s, 4),
            "ckpt_overhead_frac": round(frac, 5),
        })

    os.remove(path)
    return point


def run(smoke: bool = False, checkpoint: bool = False):
    import jax

    if smoke:
        # seconds-scale pair for the regression gate (still exercises the
        # full disk round-trip: quantize passes + memmap bin cache).  The
        # checkpointed variant always runs in smoke mode — the regression
        # gate bounds its overhead fraction on the LARGEST point, where
        # the fixed ~3-5ms/write cost is amortized the way it is at
        # production n (the small point's fraction is informational only).
        points = [(30_000, 1, 4, 1 << 13), (120_000, 1, 4, 1 << 13)]
        checkpoint = True
    else:
        # the acceptance curve: bin cache on disk, n up to >= 20M rows
        points = [(2_000_000, 1, 6, 1 << 17),
                  (8_000_000, 1, 6, 1 << 17),
                  (20_000_000, 1, 6, 1 << 17)]

    workdir = tempfile.mkdtemp(prefix="outofcore_")
    try:
        results = [_bench_point(*pt, workdir, with_checkpoint=checkpoint)
                   for pt in points]
    finally:
        for f in os.listdir(workdir):
            os.remove(os.path.join(workdir, f))
        os.rmdir(workdir)

    report = {
        "workload": {"m_num": M_NUM, "num_bins": NUM_BINS,
                     "labels": "majority-of-first-4",
                     "bagging": "none", "source": "MemmapRowSource (disk)",
                     "device": jax.default_backend(),
                     "cpu_count": os.cpu_count()},
        "points": results,
        "rows_per_sec_at_max_n": results[-1]["rows_per_sec"],
        "smoke": smoke,
        "checkpoint": checkpoint,
        "note": ("streamed hist-mode fit from a disk-backed uint8 bin "
                 "cache built by the 3-pass radix-select streaming "
                 "quantizer; device memory is bounded by chunk_size (the "
                 "level programs see only fixed-shape chunk buffers), so "
                 "rows_per_sec should be ~flat in n; peak_rss_mb is HOST "
                 "memory (labels/leaf-ids/weights are host-resident by "
                 "design, ru_maxrss is process-lifetime-monotonic so "
                 "later points inherit earlier peaks)"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("outofcore/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    import sys
    run(smoke="--smoke" in sys.argv, checkpoint="--checkpoint" in sys.argv)


if __name__ == "__main__":
    main()
