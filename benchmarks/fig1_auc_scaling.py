"""Paper Fig. 1: AUC vs training-set size × number of trees, on the §4
synthetic families (xor / majority / needle with useless variables).

Scaled to CPU-bench size; the paper's claim under test: AUC increases with
both n and T, and rote learning stays at 0.5 whenever useless variables are
present."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular, train_test_split


def run(full: bool = False) -> dict:
    sizes = [500, 2000, 8000] if not full else [1000, 4000, 16000, 64000]
    trees = [1, 3, 10]
    out = {}
    for family in ("xor", "majority", "needle"):
        for n in sizes:
            # 3 informative dims: 4-dim continuous parity needs ~1e8 rows
            # (the paper's Fig. 2 runs 3e8); bench scale uses 3
            ds = make_tabular(family, n, num_informative=3, num_useless=6,
                              seed=n)
            tr, te = train_test_split(ds)
            for T in trees:
                rf = RandomForest(
                    tree_lib.TreeParams(max_depth=12, min_records=1),
                    num_trees=T, seed=0).fit(tr)
                auc = rf.auc(te)
                out[(family, n, T)] = auc
                emit(f"fig1/{family}/n{n}/T{T}", 0.0, f"auc={auc:.4f}")
    # paper claims, bench-scale
    for family in ("xor", "majority"):
        lo = np.mean([out[(family, sizes[0], T)] for T in trees])
        hi = np.mean([out[(family, sizes[-1], T)] for T in trees])
        emit(f"fig1/{family}/more_data_helps", 0.0,
             f"auc_small={lo:.3f};auc_big={hi:.3f};claim={'OK' if hi > lo else 'FAIL'}")
        one = out[(family, sizes[-1], 1)]
        ten = out[(family, sizes[-1], 10)]
        emit(f"fig1/{family}/more_trees_help", 0.0,
             f"auc_T1={one:.3f};auc_T10={ten:.3f};claim={'OK' if ten >= one else 'FAIL'}")
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
