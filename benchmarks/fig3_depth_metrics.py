"""Paper Fig. 3 / Table 2: depth-by-depth metrics — per-level training time,
open leaves, node density, sample density, and AUC of tree/forest as the
maximum depth grows (Leo-style mixed numeric+categorical data at three
subset sizes standing in for Leo 1%/10%/100%)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular, train_test_split


def run(full: bool = False):
    base = 16000 if full else 6000
    for frac, n in (("1pct", base // 100), ("10pct", base // 10),
                    ("100pct", base)):
        # Leo-like: few numeric + high-arity categorical columns
        ds = make_tabular("majority", max(n, 200), num_informative=3,
                          num_useless=0, num_categorical=4, seed=5)
        tr, te = train_test_split(ds)
        # min_records scaled with subset size, as in the paper §5
        min_rec = max(1, int(10 * n / base))
        t0 = time.perf_counter()
        rf = RandomForest(
            tree_lib.TreeParams(max_depth=12, min_records=min_rec),
            num_trees=3, seed=0).fit(tr, collect_stats=True)
        dt = time.perf_counter() - t0
        tree0 = rf.trees[0]
        auc = rf.auc(te)
        emit(f"fig3/leo_{frac}/summary", dt * 1e6,
             f"train_s={dt:.2f};leaves={tree0.num_leaves};"
             f"node_density={tree0.node_density():.4f};"
             f"sample_density={tree0.sample_density():.4f};auc={auc:.4f}")
        for s in rf.level_stats[0]:
            emit(f"fig3/leo_{frac}/depth{s.depth}", 0.0,
                 f"open_leaves={s.open_leaves};"
                 f"bitmap_bits={s.network_bits_bitmap};"
                 f"passes={s.feature_passes}")


def main() -> None:
    run()


if __name__ == "__main__":
    main()
