"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (repo convention).
"""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in µs per call (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, list, dict)) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
