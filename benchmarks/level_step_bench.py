"""Fused level step vs the reference builder (the fig2 workload, per level).

Measures wall time per depth level for `tree.build_tree` (one fused jitted
program per level) against `tree.build_tree_reference` (the pre-fusion
builder) on the fig2 time-scaling workload, and writes the result to
``BENCH_level_step.json`` so the perf trajectory stays machine-readable
across PRs.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit
from repro.core import presort, tree as tree_lib
from repro.data.synthetic import make_tabular

OUT_PATH = os.environ.get("BENCH_LEVEL_STEP_JSON", "BENCH_level_step.json")


def _time_build(ds, sv, si, params, builder):
    """One warm build (compile) + best-of-2 timed builds of ONE tree.

    Times the level loop itself: presorting is per-forest (amortized over
    every tree), so it is prepared once outside.  Returns (seconds, levels).
    """
    kw = dict(num=ds.num, cat=ds.cat, labels=ds.labels, sorted_vals=sv,
              sorted_idx=si, arities=ds.arities, num_classes=ds.num_classes,
              params=params, seed=0)
    builder(tree_idx=0, **kw)                                   # warm jits
    best = float("inf")
    for rep in (1, 2):
        t0 = time.perf_counter()
        tree, _ = builder(tree_idx=rep, **kw)
        best = min(best, time.perf_counter() - t0)
    levels = int(tree.max_depth_reached) + 1
    return best, levels


def run(full: bool = False):
    n = 100_000 if not full else 250_000
    depth = 8
    ds = make_tabular("majority", n, num_informative=4, num_useless=4, seed=7)
    params = tree_lib.TreeParams(max_depth=depth, min_records=1)
    si = presort.presort_columns(ds.num)
    sv = presort.gather_sorted(ds.num, si)

    ref_s, ref_levels = _time_build(ds, sv, si, params,
                                    tree_lib.build_tree_reference)
    fused_s, fused_levels = _time_build(ds, sv, si, params,
                                        tree_lib.build_tree)

    def per_level_us(total_s, levels):
        return total_s / max(levels, 1) * 1e6

    ref_us = per_level_us(ref_s, ref_levels)
    fused_us = per_level_us(fused_s, fused_levels)
    speedup = ref_us / fused_us if fused_us else float("nan")

    emit(f"level_step/reference/n{n}", ref_us,
         f"levels={ref_levels};s_total={ref_s:.3f}")
    emit(f"level_step/fused/n{n}", fused_us,
         f"levels={fused_levels};s_total={fused_s:.3f}")
    emit("level_step/speedup", 0.0,
         f"x{speedup:.2f};target>=2.0:"
         f"{'OK' if speedup >= 2.0 else 'MISS'}")

    report = {
        "workload": {"family": "majority", "n": n, "m_num": 8,
                     "max_depth": depth, "backend": params.backend,
                     "device": "cpu"},
        "reference": {"total_s": round(ref_s, 4), "levels": ref_levels,
                      "per_level_us": round(ref_us, 1),
                      "rows_per_s": round(n * ref_levels / ref_s, 1)},
        "fused": {"total_s": round(fused_s, 4), "levels": fused_levels,
                  "per_level_us": round(fused_us, 1),
                  "rows_per_s": round(n * fused_levels / fused_s, 1)},
        "speedup": round(speedup, 3),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("level_step/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    run()


if __name__ == "__main__":
    main()
