"""Multi-tree batched fit vs the per-tree builder (DESIGN.md §3).

Times `RandomForest.fit` with the whole forest in one tree batch (one
jitted level program per depth for ALL trees) against the per-tree builder
(`tree_batch=1`, one program per depth PER TREE), verifies the two produce
bit-identical forests, and writes the matrix to ``BENCH_forest_batch.json``
so the perf trajectory stays machine-readable across PRs.

Two workload points: the fig2-scale n=100k headline (where the level
programs are compute-bound and the win comes from removing the per-tree
host round trips — lax.map lowering) and a small-n point (where dispatch
overhead dominates and the vmap lowering's cross-tree SIMD pays most —
the regime arXiv:1910.06853 targets).  The speedup is hardware-dependent:
per-tree dispatch overhead that batching amortizes is a far larger share
of the level time on accelerators than on a small CPU.

Smoke mode (`--smoke` / run(smoke=True)) shrinks both points so the tier-1
suite can run the whole benchmark in seconds.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_FOREST_BATCH_JSON", "BENCH_forest_batch.json")


def _fit_seconds(ds, params, n_trees, tree_batch, seed):
    """One warm fit (compile) + best-of-2 timed fits; returns (s, forest,
    level-program dispatches per timed fit)."""
    from repro.core import tree as tree_lib
    from repro.core.forest import RandomForest

    # warm with the SAME seed that is timed, so no jit compile (new padded
    # leaf counts / depth schedules) can leak into the timed region
    RandomForest(params, num_trees=n_trees, seed=seed,
                 tree_batch=tree_batch).fit(ds)              # warm jits
    best, forest, programs = float("inf"), None, 0
    for rep in (1, 2):
        c0 = (tree_lib._STEP_CALLS[0], tree_lib._BATCH_STEP_CALLS[0])
        t0 = time.perf_counter()
        rf = RandomForest(params, num_trees=n_trees, seed=seed,
                          tree_batch=tree_batch).fit(ds)
        dt = time.perf_counter() - t0
        if rep == 1:
            forest = rf          # for the cross-path parity check
            programs = (tree_lib._STEP_CALLS[0] - c0[0]
                        + tree_lib._BATCH_STEP_CALLS[0] - c0[1])
        best = min(best, dt)
    return best, forest, programs


def _bench_point(n, n_trees, depth):
    import numpy as np
    from repro.core import tree as tree_lib
    from repro.data.synthetic import make_tabular

    ds = make_tabular("majority", n, num_informative=4, num_useless=4,
                      seed=7)
    params = tree_lib.TreeParams(max_depth=depth, min_records=1)

    per_s, per_rf, per_prog = _fit_seconds(ds, params, n_trees, 1, 10)
    bat_s, bat_rf, bat_prog = _fit_seconds(ds, params, n_trees, n_trees, 10)

    # the two fits must be the same forest, bit for bit
    for ta, tb in zip(per_rf.trees, bat_rf.trees):
        np.testing.assert_array_equal(ta.feature, tb.feature)
        np.testing.assert_array_equal(ta.threshold, tb.threshold)
        np.testing.assert_array_equal(ta.value, tb.value)

    speedup = per_s / bat_s if bat_s else float("nan")
    emit(f"forest_batch/per_tree/n{n}", per_s / n_trees * 1e6,
         f"s_total={per_s:.3f};programs={per_prog}")
    emit(f"forest_batch/batched/n{n}", bat_s / n_trees * 1e6,
         f"s_total={bat_s:.3f};programs={bat_prog}")
    emit(f"forest_batch/speedup/n{n}", 0.0, f"x{speedup:.2f}")
    return {
        "n": n, "n_trees": n_trees, "max_depth": depth,
        "per_tree_s": round(per_s, 4), "batched_s": round(bat_s, 4),
        "speedup": round(speedup, 3),
        "level_programs_per_tree": per_prog,
        "level_programs_batched": bat_prog,
    }


def run(full: bool = False, smoke: bool = False):
    import jax

    if smoke:
        points = [(4_000, 8, 5)]
    else:
        # headline: the fig2 workload; secondary: the small-n regime
        points = [(100_000, 16, 8), (4_000, 16, 8)]
        if full:
            points.append((250_000, 16, 8))

    results = [_bench_point(n, t, d) for n, t, d in points]
    report = {
        "workload": {"family": "majority", "m_num": 8, "backend": "segment",
                     "device": jax.default_backend(),
                     "cpu_count": os.cpu_count()},
        "points": results,
        "speedup": results[0]["speedup"],        # headline point
        "smoke": smoke,
        "note": ("speedup = per-tree fit wall / batched fit wall for an "
                 "identical (bit-exact) forest; batched issues one level "
                 "program per depth for ALL trees, per-tree issues one per "
                 "depth per tree — the amortized dispatch/host-sync share "
                 "is hardware-dependent (largest on accelerators)"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("forest_batch/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    import sys
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
