"""Exact vs histogram (PLANET-style) split mode: quality + speed matrix.

The paper's pitch is that exact best-split search is affordable where
PLANET-era systems fell back to fixed-bin histograms; this benchmark makes
the trade-off measurable on this repro.  For each workload point it trains
the SAME forest (same seed, same tree schedule) in `split_mode="exact"`
and `split_mode="hist"` at several bucket budgets, and records held-out
AUC, the exact-vs-hist AUC delta, and the fit walls, to
``BENCH_hist_mode.json`` — the acceptance gate is |AUC delta| <= 0.01 at
num_bins=255.

The hist FAST PATH (ISSUE 5) is measured against its own plain rebuild at
the headline bucket budget: `hist_subtract=False` rebuilds every leaf's
tables each level, the default builds only the smaller child and derives
the sibling by parent − sibling.  Alongside the walls the benchmark
records (a) the per-level merged-table payload bytes (what
ShardedHistNumeric psums — ~2x smaller under subtraction) from a
collect_stats fit, and (b) a table-build microbenchmark: the fused
all-columns scatter (`splits.feature_count_tables`) vs the PR-3 era
per-column scatter loop.

Smoke mode (`--smoke` / run(smoke=True)) shrinks the point so the tier-1
suite could run it in seconds.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_HIST_MODE_JSON", "BENCH_hist_mode.json")


def _fit_seconds(train, params, n_trees, seed, collect_stats=False):
    """One warm fit (compile; optionally collect_stats for the payload
    accounting) + best-of-2 timed fits; returns (s, timed forest, warm)."""
    from repro.core.forest import RandomForest

    warm = RandomForest(params, num_trees=n_trees, seed=seed).fit(
        train, collect_stats=collect_stats)
    best, forest = float("inf"), None
    for rep in (1, 2):
        t0 = time.perf_counter()
        rf = RandomForest(params, num_trees=n_trees, seed=seed).fit(train)
        dt = time.perf_counter() - t0
        if rep == 1:
            forest = rf
        best = min(best, dt)
    return best, forest, warm


def _payload_per_level(forest):
    """Per-level merged-table payload bytes of tree 0 (collect_stats)."""
    return [s.hist_table_bytes for s in forest.level_stats[0]]


def _table_build_micro(train, B, Lp):
    """Fused all-columns table build vs the per-column scatter loop, us.

    Times exactly the per-level table-build work at a representative
    frontier width: random open-leaf ids, the real bin cache, one jitted
    program each way; best of 3 after a warm call.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import presort, splits

    si = presort.presort_columns(train.num)
    sv = presort.gather_sorted(train.num, si)
    bin_of, _ = presort.quantize(train.num, sv, B)
    n = train.n
    rng = np.random.default_rng(0)
    leaf = jnp.asarray(rng.integers(1, Lp + 1, n).astype(np.int32))
    w = jnp.ones((n,), jnp.float32)
    stats = splits.row_stats(train.labels, w, train.num_classes,
                             "classification")

    fused = jax.jit(lambda b, lf: splits.feature_count_tables(
        b, lf, w, stats, Lp, B))
    per_col = jax.jit(lambda b, lf: jax.vmap(
        lambda col: splits.categorical_count_table(
            col.astype(jnp.int32), lf, w, stats, Lp, B))(b))

    out = {}
    for name, fn in (("fused", fused), ("per_column", per_col)):
        jax.block_until_ready(fn(bin_of, leaf))              # warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(bin_of, leaf))
            best = min(best, time.perf_counter() - t0)
        out[f"{name}_us"] = round(best * 1e6, 1)
    out["speedup_fused"] = round(out["per_column_us"]
                                 / max(out["fused_us"], 1e-9), 3)
    emit(f"hist_mode/table_build/Lp{Lp}", out["fused_us"],
         f"per_column={out['per_column_us']:.0f}us;"
         f"x{out['speedup_fused']:.2f}")
    return out


def _bench_point(n, n_trees, depth, bins_list):
    import dataclasses

    from repro.core import tree as tree_lib
    from repro.data.synthetic import make_tabular, train_test_split

    # 6-of-16 majority: wide enough that candidate bagging bites (m'=4) and
    # the AUC sits just under saturation, so the exact-vs-hist delta is a
    # real number at every bucket budget.  (xor-4 at this tree budget is
    # the opposite failure: both modes hover at chance and the delta is
    # noise.)
    ds = make_tabular("majority", n, num_informative=6, num_useless=10,
                      seed=7)
    train, test = train_test_split(ds)
    exact_p = tree_lib.TreeParams(max_depth=depth, min_records=1)

    exact_s, exact_rf, _ = _fit_seconds(train, exact_p, n_trees, 10)
    exact_auc = exact_rf.auc(test)
    emit(f"hist_mode/exact/n{n}", exact_s * 1e6, f"auc={exact_auc:.4f}")

    modes = []
    payloads = {}
    headline_B = bins_list[0]
    for B in bins_list:
        hist_p = dataclasses.replace(exact_p, split_mode="hist", num_bins=B)
        variants = [("", hist_p)]
        if B == headline_B:
            # the regression-gate contrast point: plain per-level rebuild
            variants.append(("-plain", dataclasses.replace(
                hist_p, hist_subtract=False)))
        for suffix, p in variants:
            tag = f"hist{B}{suffix}"
            collect = B == headline_B
            hist_s, hist_rf, warm = _fit_seconds(train, p, n_trees, 10,
                                                 collect_stats=collect)
            hist_auc = hist_rf.auc(test)
            delta = hist_auc - exact_auc
            emit(f"hist_mode/{tag}/n{n}", hist_s * 1e6,
                 f"auc={hist_auc:.4f};delta={delta:+.4f};"
                 f"speedup=x{exact_s / hist_s:.2f}")
            if collect:
                payloads[tag] = _payload_per_level(warm)
            modes.append({
                "tag": tag, "num_bins": B,
                "hist_subtract": p.hist_subtract,
                "fit_s": round(hist_s, 4),
                "auc": round(hist_auc, 5),
                "auc_delta_vs_exact": round(delta, 5),
                "speedup_vs_exact": round(exact_s / hist_s, 3),
            })

    table_build = _table_build_micro(train, headline_B,
                                     Lp=min(64, 2 ** (depth - 1)))
    fast = next(m for m in modes if m["tag"] == f"hist{headline_B}")
    plain = next(m for m in modes if m["tag"] == f"hist{headline_B}-plain")
    fast["speedup_vs_plain_rebuild"] = round(
        plain["fit_s"] / fast["fit_s"], 3)
    pf, pp = payloads[fast["tag"]], payloads[plain["tag"]]
    payload = {
        "fast_bytes_per_level": pf, "plain_bytes_per_level": pp,
        "fast_total_bytes": int(sum(pf)), "plain_total_bytes": int(sum(pp)),
        "plain_over_fast": round(sum(pp) / max(sum(pf), 1), 3),
        "note": ("merged-table bytes per level (m·width·B·S f32) — the "
                 "ShardedHistNumeric psum payload; subtraction sends only "
                 "the packed smaller-child slots (width Lp//2+1 vs Lp+1)"),
    }
    emit(f"hist_mode/psum_payload/n{n}", 0.0,
         f"plain/fast=x{payload['plain_over_fast']:.2f}")
    return {
        "n": n, "n_trees": n_trees, "max_depth": depth,
        "exact_fit_s": round(exact_s, 4), "exact_auc": round(exact_auc, 5),
        "hist": modes, "table_build": table_build,
        "psum_payload": payload,
    }


def run(smoke: bool = False):
    import jax

    if smoke:
        points = [(4_000, 4, 5, (255, 32))]
    else:
        points = [(50_000, 8, 8, (255, 64, 16))]

    results = [_bench_point(*pt) for pt in points]
    headline = next(m for m in results[0]["hist"]
                    if m["tag"] == "hist255")
    report = {
        "workload": {"family": "majority", "m_num": 16, "backend": "segment",
                     "test_frac": 0.25, "device": jax.default_backend(),
                     "cpu_count": os.cpu_count()},
        "points": results,
        "auc_delta_at_255_bins": headline["auc_delta_vs_exact"],
        "speedup_fast_vs_plain_at_255_bins":
            headline.get("speedup_vs_plain_rebuild"),
        "smoke": smoke,
        "note": ("same forest schedule (seed, trees, depth) trained with "
                 "split_mode='exact' (the paper's midpoint-exhaustive "
                 "search) vs 'hist' (PLANET-style: <= num_bins quantile "
                 "buckets per column, boundaries scored from per-leaf "
                 "(bin x class) count tables); auc on a 25% holdout; "
                 "acceptance gate |auc_delta_at_255_bins| <= 0.01.  "
                 "hist<B> runs the ISSUE-5 fast path (bit-packed bin "
                 "cache + fused table build + parent-sibling "
                 "subtraction); hist<B>-plain disables subtraction"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("hist_mode/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    import sys
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
