"""Exact vs histogram (PLANET-style) split mode: quality + speed matrix.

The paper's pitch is that exact best-split search is affordable where
PLANET-era systems fell back to fixed-bin histograms; this benchmark makes
the trade-off measurable on this repro.  For each workload point it trains
the SAME forest (same seed, same tree schedule) in `split_mode="exact"`
and `split_mode="hist"` at several bucket budgets, and records held-out
AUC, the exact-vs-hist AUC delta, and the fit walls, to
``BENCH_hist_mode.json`` — the acceptance gate is |AUC delta| <= 0.01 at
num_bins=255.

Smoke mode (`--smoke` / run(smoke=True)) shrinks the point so the tier-1
suite could run it in seconds.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_HIST_MODE_JSON", "BENCH_hist_mode.json")


def _fit_seconds(train, params, n_trees, seed):
    """One warm fit (compile) + best-of-2 timed fits; returns (s, forest)."""
    from repro.core.forest import RandomForest

    RandomForest(params, num_trees=n_trees, seed=seed).fit(train)  # warm
    best, forest = float("inf"), None
    for rep in (1, 2):
        t0 = time.perf_counter()
        rf = RandomForest(params, num_trees=n_trees, seed=seed).fit(train)
        dt = time.perf_counter() - t0
        if rep == 1:
            forest = rf
        best = min(best, dt)
    return best, forest


def _bench_point(n, n_trees, depth, bins_list):
    import dataclasses

    from repro.core import tree as tree_lib
    from repro.data.synthetic import make_tabular, train_test_split

    # 6-of-16 majority: wide enough that candidate bagging bites (m'=4) and
    # the AUC sits just under saturation, so the exact-vs-hist delta is a
    # real number at every bucket budget.  (xor-4 at this tree budget is
    # the opposite failure: both modes hover at chance and the delta is
    # noise.)
    ds = make_tabular("majority", n, num_informative=6, num_useless=10,
                      seed=7)
    train, test = train_test_split(ds)
    exact_p = tree_lib.TreeParams(max_depth=depth, min_records=1)

    exact_s, exact_rf = _fit_seconds(train, exact_p, n_trees, 10)
    exact_auc = exact_rf.auc(test)
    emit(f"hist_mode/exact/n{n}", exact_s * 1e6, f"auc={exact_auc:.4f}")

    modes = []
    for B in bins_list:
        hist_p = dataclasses.replace(exact_p, split_mode="hist", num_bins=B)
        hist_s, hist_rf = _fit_seconds(train, hist_p, n_trees, 10)
        hist_auc = hist_rf.auc(test)
        delta = hist_auc - exact_auc
        emit(f"hist_mode/hist{B}/n{n}", hist_s * 1e6,
             f"auc={hist_auc:.4f};delta={delta:+.4f};"
             f"speedup=x{exact_s / hist_s:.2f}")
        modes.append({
            "num_bins": B, "fit_s": round(hist_s, 4),
            "auc": round(hist_auc, 5),
            "auc_delta_vs_exact": round(delta, 5),
            "speedup_vs_exact": round(exact_s / hist_s, 3),
        })
    return {
        "n": n, "n_trees": n_trees, "max_depth": depth,
        "exact_fit_s": round(exact_s, 4), "exact_auc": round(exact_auc, 5),
        "hist": modes,
    }


def run(smoke: bool = False):
    import jax

    if smoke:
        points = [(4_000, 4, 5, (255, 32))]
    else:
        points = [(50_000, 8, 8, (255, 64, 16))]

    results = [_bench_point(*pt) for pt in points]
    headline = next(m for m in results[0]["hist"] if m["num_bins"] == 255)
    report = {
        "workload": {"family": "majority", "m_num": 16, "backend": "segment",
                     "test_frac": 0.25, "device": jax.default_backend(),
                     "cpu_count": os.cpu_count()},
        "points": results,
        "auc_delta_at_255_bins": headline["auc_delta_vs_exact"],
        "smoke": smoke,
        "note": ("same forest schedule (seed, trees, depth) trained with "
                 "split_mode='exact' (the paper's midpoint-exhaustive "
                 "search) vs 'hist' (PLANET-style: <= num_bins quantile "
                 "buckets per column, boundaries scored from per-leaf "
                 "(bin x class) count tables); auc on a 25% holdout; "
                 "acceptance gate |auc_delta_at_255_bins| <= 0.01"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("hist_mode/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    import sys
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
