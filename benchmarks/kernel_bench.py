"""Supersplit engine micro-bench: Pallas split_scan kernel (interpret on
CPU) vs the jnp scan / segment backends — per-call µs and rows/s."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import splits
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    n, m, L, C = 16384, 4, 7, 2
    num = rng.normal(size=(n, m)).astype(np.float32)
    y = rng.integers(0, C, n).astype(np.int32)
    w = rng.integers(0, 3, n).astype(np.float32)
    leaf = rng.integers(0, L + 1, n).astype(np.int32)
    si = np.argsort(num.T, axis=-1, kind="stable").astype(np.int32)
    sv = jnp.asarray(np.take_along_axis(num.T, si, -1))
    si = jnp.asarray(si)
    leaf_j, w_j, y_j = map(jnp.asarray, (leaf, w, y))
    stats = splits.row_stats(y_j, w_j, C, "classification")
    cand = jnp.asarray(np.ones((m, L + 1), bool))

    import jax
    def seg(sv, si, cand):
        return jax.vmap(lambda v, s, c: splits.best_numeric_split_segment(
            v, leaf_j[s], w_j[s], stats[s], c, L))(sv, si, cand)

    def scn(sv, si, cand):
        return jax.vmap(lambda v, s, c: splits.best_numeric_split_scan(
            v, leaf_j[s], w_j[s], stats[s], c, L))(sv, si, cand)

    def ker(sv, si, cand):
        return ops.split_scan_supersplit(sv, si, leaf_j, w_j, y_j, cand, L,
                                         bn=512)

    for name, fn in (("segment", seg), ("scan", scn),
                     ("pallas_interpret", ker)):
        us = timeit(fn, sv, si, cand, warmup=1, iters=3)
        emit(f"kernel/split_{name}", us,
             f"rows_per_s={m * n / (us / 1e6):.3e}")


def main() -> None:
    run()


if __name__ == "__main__":
    main()
