"""Perf-regression gate: fresh smoke benchmarks vs the committed baseline.

The committed ``BENCH_*.json`` files record full-scale runs whose walls
are not reproducible in CI time, so the gate works on the SMOKE variants:
``BENCH_smoke_baseline.json`` (committed) holds the smoke-scale walls of
the machine that produced it, and this script re-runs the smoke
benchmarks (forest / hist / dist) and fails — exit 1 — when any tracked
wall regressed by more than ``--factor`` (default 2×, absorbing CI-box
noise while catching real cliffs like a lost jit cache or a fallen-back
per-tree path).

    python -m benchmarks.check_regression            # gate (exit 1 on >2x)
    python -m benchmarks.check_regression --update    # rewrite the baseline
    python -m benchmarks.check_regression --factor 3  # custom threshold

Wired into the `-m slow` suite (tests/test_bench_regression.py).
Structural counters (level-program counts) are compared EXACTLY — a
changed dispatch count is a behavior change, not noise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_PATH = os.environ.get("BENCH_SMOKE_BASELINE_JSON",
                               os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_smoke_baseline.json"))


def _collect_smoke_metrics(tmpdir) -> dict:
    """Run every smoke benchmark (JSON sinks redirected into `tmpdir`),
    return {metric: value}.

    Walls (``*_s``) are gated by ratio; ``programs::*`` counters exactly.
    The BENCH_*_JSON overrides and the module reloads that pick them up
    are undone on exit, so later bench runs in the same process write to
    their normal locations again.
    """
    import contextlib
    import importlib
    import unittest.mock

    from benchmarks import (dist_batch_bench, forest_batch_bench,
                            hist_mode_bench, outofcore_bench)
    mods = (forest_batch_bench, hist_mode_bench, dist_batch_bench,
            outofcore_bench)
    with contextlib.ExitStack() as stack:
        for mod in mods:           # LIFO: these run LAST, after the env
            stack.callback(importlib.reload, mod)   # restore below
        stack.enter_context(unittest.mock.patch.dict(os.environ, {
            "BENCH_FOREST_BATCH_JSON": os.path.join(tmpdir, "forest.json"),
            "BENCH_HIST_MODE_JSON": os.path.join(tmpdir, "hist.json"),
            "BENCH_DIST_BATCH_JSON": os.path.join(tmpdir, "dist.json"),
            "BENCH_OUTOFCORE_JSON": os.path.join(tmpdir, "outofcore.json")}))
        for mod in mods:
            importlib.reload(mod)                   # pick up the overrides
        return _run_smoke_benches(*mods)


def _run_smoke_benches(forest_batch_bench, hist_mode_bench,
                       dist_batch_bench, outofcore_bench) -> dict:
    metrics: dict = {}
    forest = forest_batch_bench.run(smoke=True)
    for p in forest["points"]:
        metrics[f"forest/batched_s/n{p['n']}"] = p["batched_s"]
        metrics[f"forest/per_tree_s/n{p['n']}"] = p["per_tree_s"]
        metrics[f"programs::forest/batched/n{p['n']}"] = \
            p["level_programs_batched"]
    hist = hist_mode_bench.run(smoke=True)
    for p in hist["points"]:
        metrics[f"hist/exact_s/n{p['n']}"] = p["exact_fit_s"]
        for mode in p["hist"]:
            # tagged since ISSUE 5: hist<B> = the subtraction fast path,
            # hist<B>-plain = per-level rebuild — both gated so a lost
            # fast path shows up as a wall regression
            tag = mode.get("tag", f"hist{mode['num_bins']}")
            metrics[f"hist/{tag}_s/n{p['n']}"] = mode["fit_s"]
    dist = dist_batch_bench.run(smoke=True)
    for c in dist["configs"]:
        metrics[f"dist/{c['mode']}/batched_s"] = c["batched_s"]
        metrics[f"programs::dist/{c['mode']}/batched"] = \
            c["level_programs_batched"]
    ooc = outofcore_bench.run(smoke=True)
    for p in ooc["points"]:
        metrics[f"outofcore/fit_s/n{p['n']}"] = p["fit_s"]
        metrics[f"outofcore/build_s/n{p['n']}"] = p["build_s"]
        # dispatch count is structural: a retrace-per-chunk bug would
        # not change it, but a lost accumulation loop would
        metrics[f"programs::outofcore/chunks/n{p['n']}"] = \
            p["chunk_programs"]
    return metrics


def check(fresh: dict, baseline: dict, factor: float) -> list[str]:
    failures = []
    for name, base in baseline.items():
        if name not in fresh:
            failures.append(f"metric disappeared: {name}")
            continue
        now = fresh[name]
        if name.startswith("programs::"):
            if now != base:
                failures.append(
                    f"{name}: level-program count changed {base} -> {now}")
        elif base > 0 and now > factor * base:
            failures.append(
                f"{name}: {now:.3f}s vs baseline {base:.3f}s "
                f"(x{now / base:.2f} > x{factor})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed smoke baseline")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated slowdown ratio (default 2.0)")
    args = ap.parse_args(argv)

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        fresh = _collect_smoke_metrics(tmp)

    if args.update or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump({"metrics": fresh,
                       "note": ("smoke-scale walls (seconds) + level-"
                                "program counters; refresh with "
                                "`python -m benchmarks.check_regression "
                                "--update` on the reference box")},
                      f, indent=2)
            f.write("\n")
        print(f"baseline written: {BASELINE_PATH} ({len(fresh)} metrics)")
        return 0

    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["metrics"]
    failures = check(fresh, baseline, args.factor)
    for name in sorted(fresh):
        base = baseline.get(name)
        ref = f" (baseline {base})" if base is not None else " (NEW)"
        print(f"  {name}: {fresh[name]}{ref}")
    if failures:
        print("\nPERF REGRESSION:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print(f"\nok: {len(baseline)} metrics within x{args.factor} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
