"""Perf-regression gate: fresh smoke benchmarks vs the committed baseline.

The committed ``BENCH_*.json`` files record full-scale runs whose walls
are not reproducible in CI time, so the gate works on the SMOKE variants:
``BENCH_smoke_baseline.json`` (committed) holds the smoke-scale walls of
the machine that produced it, and this script re-runs the smoke
benchmarks (forest / hist / dist) and fails — exit 1 — when any tracked
wall regressed by more than ``--factor`` (default 2×, absorbing CI-box
noise while catching real cliffs like a lost jit cache or a fallen-back
per-tree path).

    python -m benchmarks.check_regression            # gate (exit 1 on >2x)
    python -m benchmarks.check_regression --update    # rewrite the baseline
    python -m benchmarks.check_regression --factor 3  # custom threshold

Wired into the `-m slow` suite (tests/test_bench_regression.py).
Structural counters (level-program counts) are compared EXACTLY — a
changed dispatch count is a behavior change, not noise.  ``gate::``
metrics are checked against ABSOLUTE bounds (`GATE_BOUNDS`) rather than
a baseline ratio — e.g. the checkpoint-write overhead fraction of the
smoke out-of-core fit must stay <= 5% regardless of the box.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_PATH = os.environ.get("BENCH_SMOKE_BASELINE_JSON",
                               os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_smoke_baseline.json"))

# Absolute bounds for ``gate::`` metrics (checked against the FRESH run
# only — these are invariants of the implementation, not of the box that
# wrote the baseline).  Prefix-matched against the metric name.
GATE_BOUNDS = {
    # fraction of the checkpointed streamed-fit wall spent inside
    # checkpoint disk writes (ISSUE 10 acceptance: <= 5%)
    "gate::outofcore/ckpt_overhead_frac/": 0.05,
}


def _point_metric(point: dict, key: str, bench: str):
    """Index a benchmark point dict with a diagnosable failure mode.

    A missing key means the benchmark module and this gate have drifted
    apart (e.g. a renamed field) — that should read as exactly that, not
    as a bare KeyError traceback.
    """
    try:
        return point[key]
    except KeyError:
        raise SystemExit(
            f"check_regression: smoke benchmark '{bench}' returned a point "
            f"without the key '{key}' (point: {sorted(point)}); the "
            f"benchmark schema and benchmarks/check_regression.py have "
            f"drifted apart — update _run_smoke_benches") from None


def _collect_smoke_metrics(tmpdir) -> dict:
    """Run every smoke benchmark (JSON sinks redirected into `tmpdir`),
    return {metric: value}.

    Walls (``*_s``) are gated by ratio; ``programs::*`` counters exactly.
    The BENCH_*_JSON overrides and the module reloads that pick them up
    are undone on exit, so later bench runs in the same process write to
    their normal locations again.
    """
    import contextlib
    import importlib
    import unittest.mock

    from benchmarks import (dist_batch_bench, forest_batch_bench,
                            hist_mode_bench, outofcore_bench)
    mods = (forest_batch_bench, hist_mode_bench, dist_batch_bench,
            outofcore_bench)
    with contextlib.ExitStack() as stack:
        for mod in mods:           # LIFO: these run LAST, after the env
            stack.callback(importlib.reload, mod)   # restore below
        stack.enter_context(unittest.mock.patch.dict(os.environ, {
            "BENCH_FOREST_BATCH_JSON": os.path.join(tmpdir, "forest.json"),
            "BENCH_HIST_MODE_JSON": os.path.join(tmpdir, "hist.json"),
            "BENCH_DIST_BATCH_JSON": os.path.join(tmpdir, "dist.json"),
            "BENCH_OUTOFCORE_JSON": os.path.join(tmpdir, "outofcore.json")}))
        for mod in mods:
            importlib.reload(mod)                   # pick up the overrides
        return _run_smoke_benches(*mods)


def _run_smoke_benches(forest_batch_bench, hist_mode_bench,
                       dist_batch_bench, outofcore_bench) -> dict:
    metrics: dict = {}
    forest = forest_batch_bench.run(smoke=True)
    for p in forest["points"]:
        n = _point_metric(p, "n", "forest")
        metrics[f"forest/batched_s/n{n}"] = \
            _point_metric(p, "batched_s", "forest")
        metrics[f"forest/per_tree_s/n{n}"] = \
            _point_metric(p, "per_tree_s", "forest")
        metrics[f"programs::forest/batched/n{n}"] = \
            _point_metric(p, "level_programs_batched", "forest")
    hist = hist_mode_bench.run(smoke=True)
    for p in hist["points"]:
        n = _point_metric(p, "n", "hist")
        metrics[f"hist/exact_s/n{n}"] = _point_metric(p, "exact_fit_s", "hist")
        for mode in _point_metric(p, "hist", "hist"):
            # tagged since ISSUE 5: hist<B> = the subtraction fast path,
            # hist<B>-plain = per-level rebuild — both gated so a lost
            # fast path shows up as a wall regression
            tag = mode.get("tag", f"hist{_point_metric(mode, 'num_bins', 'hist')}")
            metrics[f"hist/{tag}_s/n{n}"] = _point_metric(mode, "fit_s", "hist")
    dist = dist_batch_bench.run(smoke=True)
    for c in dist["configs"]:
        mode = _point_metric(c, "mode", "dist")
        metrics[f"dist/{mode}/batched_s"] = _point_metric(c, "batched_s", "dist")
        metrics[f"programs::dist/{mode}/batched"] = \
            _point_metric(c, "level_programs_batched", "dist")
    ooc = outofcore_bench.run(smoke=True)
    for p in ooc["points"]:
        n = _point_metric(p, "n", "outofcore")
        metrics[f"outofcore/fit_s/n{n}"] = _point_metric(p, "fit_s", "outofcore")
        metrics[f"outofcore/build_s/n{n}"] = \
            _point_metric(p, "build_s", "outofcore")
        # dispatch count is structural: a retrace-per-chunk bug would
        # not change it, but a lost accumulation loop would
        metrics[f"programs::outofcore/chunks/n{n}"] = \
            _point_metric(p, "chunk_programs", "outofcore")
    # absolute gate: checkpoint writes must stay a rounding error on the
    # fit wall (smoke mode always measures the checkpointed fit).  Gated
    # on the largest smoke point only — the per-snapshot cost is a fixed
    # few ms, so the fraction at tiny n overstates what production-scale
    # fits (the thing the 5% bound protects) would ever see.
    big = max(ooc["points"], key=lambda p: _point_metric(p, "n", "outofcore"))
    metrics[f"gate::outofcore/ckpt_overhead_frac/n{big['n']}"] = \
        _point_metric(big, "ckpt_overhead_frac", "outofcore")
    return metrics


def check(fresh: dict, baseline: dict, factor: float) -> list[str]:
    failures = []
    for name, base in baseline.items():
        if name not in fresh:
            failures.append(f"metric disappeared: {name}")
            continue
        if name.startswith("gate::"):
            continue                    # absolute-bound metrics, below
        now = fresh[name]
        if name.startswith("programs::"):
            if now != base:
                failures.append(
                    f"{name}: level-program count changed {base} -> {now}")
        elif base > 0 and now > factor * base:
            failures.append(
                f"{name}: {now:.3f}s vs baseline {base:.3f}s "
                f"(x{now / base:.2f} > x{factor})")
    # gate:: metrics are implementation invariants — checked against the
    # fresh run's absolute value, never a baseline ratio
    for name, now in fresh.items():
        if not name.startswith("gate::"):
            continue
        bound = next((b for pre, b in GATE_BOUNDS.items()
                      if name.startswith(pre)), None)
        if bound is None:
            failures.append(f"{name}: no absolute bound registered in "
                            "GATE_BOUNDS")
        elif now > bound:
            failures.append(
                f"{name}: {now:.4f} exceeds absolute bound {bound}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed smoke baseline")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated slowdown ratio (default 2.0)")
    args = ap.parse_args(argv)

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        fresh = _collect_smoke_metrics(tmp)

    if args.update or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump({"metrics": fresh,
                       "note": ("smoke-scale walls (seconds) + level-"
                                "program counters; refresh with "
                                "`python -m benchmarks.check_regression "
                                "--update` on the reference box")},
                      f, indent=2)
            f.write("\n")
        print(f"baseline written: {BASELINE_PATH} ({len(fresh)} metrics)")
        return 0

    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["metrics"]
    failures = check(fresh, baseline, args.factor)
    for name in sorted(fresh):
        base = baseline.get(name)
        ref = f" (baseline {base})" if base is not None else " (NEW)"
        print(f"  {name}: {fresh[name]}{ref}")
    if failures:
        print("\nPERF REGRESSION:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print(f"\nok: {len(baseline)} metrics within x{args.factor} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
