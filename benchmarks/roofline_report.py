"""Render the §Roofline table from dry-run JSONL records
(written by `python -m repro.launch.dryrun --out ...`)."""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            if line.strip():
                recs.append(json.loads(line))
    # last record per (arch, shape, mesh) wins
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def render(recs: list[dict]) -> str:
    lines = []
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'mesh':7s} | mem/dev GiB | "
           f"compute ms | memory ms | coll ms | dominant | useful |")
    lines.append(hdr)
    lines.append("|" + "-" * (len(hdr) - 2) + "|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"],
                                         order.get(r["shape"], 9))):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']:24s} | {r['shape']:11s} | "
                         f"{r['mesh']:7s} | SKIPPED: {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']:24s} | {r['shape']:11s} | "
                         f"{r['mesh']:7s} | ERROR: {r['error'][:60]} |")
            continue
        mem = r["memory"]["total_bytes_per_device"] / 2**30
        rl = r.get("roofline")
        if rl:
            lines.append(
                f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} | "
                f"{mem:11.2f} | {rl['compute_s']*1e3:10.2f} | "
                f"{rl['memory_s']*1e3:9.2f} | {rl['collective_s']*1e3:7.2f} | "
                f"{rl['dominant']:8s} | {rl['useful_flops_ratio']:6.3f} |")
        else:
            lines.append(
                f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} | "
                f"{mem:11.2f} | {'—':>10s} | {'—':>9s} | {'—':>7s} | "
                f"{'—':8s} | {'—':>6s} |")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.jsonl"
    print(render(load(path)))


if __name__ == "__main__":
    main()
