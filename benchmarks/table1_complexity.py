"""Paper Table 1: complexity accounting for DRF vs Sliq/Sprint baselines.

The DRF row is MEASURED from the instrumented tree builder (LevelStats):
network bits (1-bit bitmap broadcasts + supersplit payloads), class-list
bits (n·⌈log2(ℓ+1)⌉), and feature passes per level.  The Sliq/Sprint rows
are the paper's analytic formulas evaluated at the same (n, m, D) so the
asymptotic comparison in the paper is reproduced numerically."""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import emit
from repro.core import tree as tree_lib
from repro.core.forest import RandomForest
from repro.data.synthetic import make_tabular


def run():
    n, m_inf, m_useless = 8000, 4, 4
    m = m_inf + m_useless
    ds = make_tabular("majority", n, m_inf, m_useless, seed=0)

    for usb in (False, True):
        rf = RandomForest(
            tree_lib.TreeParams(max_depth=8, min_records=1, usb=usb),
            num_trees=1, seed=0).fit(ds, collect_stats=True)
        stats = rf.level_stats[0]
        D = len(stats)
        bitmap_bits = sum(s.network_bits_bitmap for s in stats)
        ss_bits = sum(s.network_bits_supersplit for s in stats)
        passes = sum(s.feature_passes for s in stats)
        rows = sum(s.rows_scanned for s in stats)
        cls_bits = max(s.class_list_bits for s in stats)
        tag = "usb" if usb else "classic"
        emit(f"table1/drf_{tag}/network_bitmap_bits", 0.0,
             f"measured={bitmap_bits};paper_Dn={D * n}")
        emit(f"table1/drf_{tag}/network_supersplit_bits", 0.0,
             f"measured={ss_bits}")
        emit(f"table1/drf_{tag}/class_list_bits", 0.0,
             f"measured={cls_bits};paper_nlog2M={n * math.ceil(math.log2(max(s.open_leaves for s in stats) + 1))}")
        emit(f"table1/drf_{tag}/feature_passes", 0.0,
             f"measured={passes};rows_scanned={rows}")

    # analytic baseline rows at the same scale (paper Table 1 formulas)
    mp = math.isqrt(m)
    Dd = 8
    value_bits, idx_bits = 32, 64
    emit("table1/analytic/sliq_read_bits", 0.0,
         f"{(m + 1) * n * Dd * (value_bits + idx_bits)}  # (m''+1)nD([value]+[idx])")
    emit("table1/analytic/sprint_network_bits", 0.0,
         f"{n * idx_bits + Dd * n * idx_bits}  # n idx bagging + Dn idx broadcasts")
    emit("table1/analytic/drf_network_bits", 0.0,
         f"{Dd * n}  # Dn bits in D allreduce — 64x less than Sprint")
    emit("table1/analytic/drf_memory_bits_per_sample", 0.0,
         f"{1 + math.ceil(math.log2(256))}  # 1+log2(M) vs Sliq {value_bits + 16}")


def main() -> None:
    run()


if __name__ == "__main__":
    main()
