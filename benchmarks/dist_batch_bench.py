"""Sharded training through the batched builder (ISSUE 4 tentpole bench).

Before the SplitEngine refactor, distributed training was the ONE
configuration that lost the multi-tree batch amortization: `fit` routed a
`supersplit_fn` to the per-tree builder, T·D level programs per forest.
This benchmark trains the same forest on a 2×4 forced-host-device mesh
(data × model, the distributed test topology) through BOTH paths —
`tree_batch=1` (per-tree, one mesh program per depth PER TREE) and
`tree_batch=T` (batched, one mesh program per depth for ALL trees) — for
the exact AND the histogram engine, verifies bit-identical forests, and
records the programs-per-depth counts and fit walls to
``BENCH_dist_batch.json``.  The acceptance signal is `level_programs_
batched == D` (not T·D) for every sharded configuration.

Runs its workload in a SUBPROCESS so the forced 8-device host platform
never leaks into the parent (same pattern as tests/test_distributed.py).
Smoke mode shrinks n/T/depth to seconds-scale.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_DIST_BATCH_JSON", "BENCH_dist_batch.json")

_WORKLOAD = """
    import json, time
    import numpy as np
    from repro.core import distributed, tree as tree_lib
    from repro.core.dataset import from_numpy
    from repro.core.forest import RandomForest
    from repro.launch.mesh import make_host_mesh

    n, n_trees, depth = {n}, {n_trees}, {depth}
    mesh = make_host_mesh(2, 4)
    rng = np.random.default_rng(7)
    num = rng.normal(size=(n, 8)).astype(np.float32)
    y = ((num[:, 0] + num[:, 1] * num[:, 2]) > 0).astype(np.int32)
    ds = from_numpy(num, None, y)

    def fit_timed(params, engine, tree_batch):
        RandomForest(params, num_trees=n_trees, seed=10,
                     tree_batch=tree_batch).fit(ds, engine=engine)  # warm
        best, rf, programs = float('inf'), None, 0
        for rep in (1, 2):
            c0 = (tree_lib._STEP_CALLS[0], tree_lib._BATCH_STEP_CALLS[0])
            t0 = time.perf_counter()
            out = RandomForest(params, num_trees=n_trees, seed=10,
                               tree_batch=tree_batch).fit(ds, engine=engine)
            dt = time.perf_counter() - t0
            if rep == 1:
                rf = out
                programs = (tree_lib._STEP_CALLS[0] - c0[0]
                            + tree_lib._BATCH_STEP_CALLS[0] - c0[1])
            best = min(best, dt)
        return best, rf, programs

    configs = [
        ('exact', tree_lib.TreeParams(max_depth=depth),
         distributed.make_2d_sharded_supersplit(mesh)),
        ('hist', tree_lib.TreeParams(max_depth=depth, split_mode='hist',
                                     num_bins=64),
         distributed.make_hist_sharded_supersplit(mesh)),
    ]
    rows = []
    for mode, params, engine in configs:
        local_rf = RandomForest(params, num_trees=n_trees, seed=10,
                                tree_batch=n_trees).fit(ds)
        per_s, per_rf, per_prog = fit_timed(params, engine, 1)
        bat_s, bat_rf, bat_prog = fit_timed(params, engine, n_trees)
        D = max(t.max_depth_reached for t in bat_rf.trees)
        for ta, tb, tc in zip(local_rf.trees, per_rf.trees, bat_rf.trees):
            np.testing.assert_array_equal(ta.feature, tb.feature)
            np.testing.assert_array_equal(ta.feature, tc.feature)
            np.testing.assert_array_equal(ta.threshold, tc.threshold)
            np.testing.assert_array_equal(ta.value, tc.value)
        rows.append(dict(
            mode=mode, n=n, n_trees=n_trees, max_depth=depth,
            deepest_tree=D,
            per_tree_s=round(per_s, 4), batched_s=round(bat_s, 4),
            speedup=round(per_s / bat_s, 3) if bat_s else None,
            level_programs_per_tree=per_prog,
            level_programs_batched=bat_prog,
            bit_identical_to_local=True))
    print('JSON::' + json.dumps(rows))
"""


def run(smoke: bool = False):
    n, n_trees, depth = (1024, 4, 4) if smoke else (8192, 8, 6)
    code = textwrap.dedent(_WORKLOAD.format(n=n, n_trees=n_trees,
                                            depth=depth))
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(f"dist bench subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    rows = json.loads(
        next(l for l in out.stdout.splitlines()
             if l.startswith("JSON::"))[len("JSON::"):])
    for r in rows:
        assert r["level_programs_batched"] < r["level_programs_per_tree"]
        assert r["level_programs_batched"] <= r["max_depth"] + 1
        emit(f"dist_batch/{r['mode']}/batched/n{r['n']}",
             r["batched_s"] * 1e6,
             f"programs={r['level_programs_batched']};"
             f"speedup=x{r['speedup']:.2f}")
    report = {
        "workload": {"mesh": "2x4 host devices (data x model)", "m_num": 8,
                     "backend": "segment",
                     "cpu_count": os.cpu_count()},
        "configs": rows,
        "smoke": smoke,
        "note": ("same sharded forest trained per-tree (tree_batch=1, T*D "
                 "mesh programs) vs batched (tree_batch=T, D programs — "
                 "the ISSUE 4 acceptance shape); forests verified "
                 "bit-identical to the LOCAL batched builder for exact and "
                 "hist engines; walls from a 2-core CPU host mesh, where "
                 "the removed per-tree dispatch/host-sync share is far "
                 "smaller than on a real accelerator mesh"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("dist_batch/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
