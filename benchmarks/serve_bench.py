"""Serving micro-benchmark: p50 single-row latency off a loaded forest.

The serving path under test is exactly what a long-lived inference
process runs (ROADMAP "serving export path" wire-up): train a small
forest, `PackedForest.save` it to one versioned .npz, `ForestServer.load`
it back (which compiles the whole-forest descent with a warm-up call),
then time per-call latency of `predict` on single rows — p50/p90 over a
few hundred calls, no compile time included (that is the point of the
warm-up).  Results go to ``BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from benchmarks.common import emit

OUT_PATH = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")


def run(smoke: bool = False, calls: int = 300):
    import jax
    import numpy as np

    from repro.core import tree as tree_lib
    from repro.core.forest import RandomForest
    from repro.data.synthetic import make_tabular
    from repro.serve.engine import ForestServer

    n, n_trees, depth = (2_000, 4, 5) if smoke else (20_000, 32, 8)
    ds = make_tabular("majority", n, num_informative=6, num_useless=10,
                      seed=7)
    rf = RandomForest(tree_lib.TreeParams(max_depth=depth),
                      num_trees=n_trees, seed=1).fit(ds)

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "forest.npz")
        rf.packed.save(path)
        t0 = time.perf_counter()
        srv = ForestServer.load(path)          # includes the warm-up jit
        load_s = time.perf_counter() - t0

    row = np.asarray(ds.num[:1])
    lats = []
    for _ in range(calls):
        t0 = time.perf_counter()
        jax.block_until_ready(srv.predict(row))
        lats.append(time.perf_counter() - t0)
    lats = np.sort(np.asarray(lats))
    p50 = float(lats[len(lats) // 2])
    p90 = float(lats[int(len(lats) * 0.9)])

    emit(f"serve/p50_single_row/T{n_trees}", p50 * 1e6,
         f"p90={p90 * 1e6:.0f}us;load={load_s:.2f}s")
    report = {
        "n_trees": n_trees, "max_depth": depth, "calls": calls,
        "load_and_warmup_s": round(load_s, 4),
        "p50_single_row_us": round(p50 * 1e6, 1),
        "p90_single_row_us": round(p90 * 1e6, 1),
        "smoke": smoke,
        "note": ("ForestServer.load (PackedForest .npz + warm-up jit) then "
                 "per-call wall of predict on a single row; the warm-up "
                 "means no call pays the descent trace"),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    emit("serve/json", 0.0, OUT_PATH)
    return report


def main() -> None:
    import sys
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
